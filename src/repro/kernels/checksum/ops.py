"""Public checksum ops: byte-buffer digests with backend dispatch."""
from __future__ import annotations

from typing import Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.checksum.kernel import checksum as checksum_pallas
from repro.kernels.checksum.ref import checksum_ref

_BLOCK_BYTES = 512 * 128 * 4  # block_rows=512 tiles of 128 uint32 lanes


def digest_array(x: jnp.ndarray, *, use_pallas: bool = None) -> Tuple[int, int]:
    """(s1, s2) digest of a 1-D uint32 array (padded to block multiple)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    n = x.shape[0]
    block_elems = _BLOCK_BYTES // 4
    pad = (-n) % block_elems
    if pad:
        x = jnp.pad(x, (0, pad))
    if use_pallas:
        out = checksum_pallas(x)
    else:
        out = jax.jit(checksum_ref)(x)
    s1, s2 = np.asarray(out)
    return int(s1), int(s2)


def digest_bytes(buf: Union[bytes, bytearray, np.ndarray]) -> Tuple[int, int]:
    """(s1, s2) digest of a raw byte buffer (zero-padded to 4-byte words)."""
    arr = _as_u8(buf)
    pad = (-arr.size) % 4
    if pad:
        arr = np.concatenate([arr, np.zeros(pad, dtype=np.uint8)])
    words = arr.view(np.uint32)
    return digest_array(jnp.asarray(words))


def _as_u8(buf) -> np.ndarray:
    return (
        np.frombuffer(buf, dtype=np.uint8)
        if isinstance(buf, (bytes, bytearray, memoryview))
        else np.ascontiguousarray(buf).view(np.uint8).ravel()
    )


@jax.jit
def _rows_checksum(x2: jnp.ndarray) -> jnp.ndarray:
    """Per-row [s1, s2] of a (rows, words) uint32 matrix — the same sums the
    blocked kernel computes, batched so one dispatch digests every chunk."""
    idx = jnp.arange(x2.shape[1], dtype=jnp.uint32)[None, :] + jnp.uint32(1)
    s1 = jnp.sum(x2, axis=1, dtype=jnp.uint32)
    s2 = jnp.sum(x2 * idx, axis=1, dtype=jnp.uint32)
    return jnp.stack([s1, s2], axis=1)


def _rows_checksum_np(body: np.ndarray) -> list:
    """Host fallback of :func:`_rows_checksum`: identical mod-2^32 sums via
    numpy's wrapping uint32 arithmetic — no device copy, no dispatch."""
    idx = (np.arange(body.shape[1], dtype=np.uint32) + np.uint32(1))[None, :]
    with np.errstate(over="ignore"):
        s1 = np.sum(body, axis=1, dtype=np.uint32)
        s2 = np.sum(body * idx, axis=1, dtype=np.uint32)
    return [[int(a), int(b)] for a, b in zip(s1, s2)]


def digest_chunks(buf: Union[bytes, bytearray, np.ndarray],
                  chunk_bytes: int, *, use_pallas: bool = None) -> list:
    """Per-chunk (s1, s2) digests of ``buf`` split every ``chunk_bytes``.

    Bit-identical to ``digest_bytes(chunk)`` on each slice (zero padding is
    digest-neutral: both sums ignore zero words), but the full-size chunks go
    through **one** batched pass instead of one call per chunk — this is the
    delta codec's change-detection pass, where per-call overhead would
    otherwise dominate a mostly-clean checkpoint.  On TPU the batched rows
    run on-device next to the blocked kernel; on CPU the identical modular
    sums run directly in numpy (the device round-trip costs ~3x the math).
    The ragged tail chunk (if any) is digested separately.  Returns
    ``[[s1, s2], ...]``.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    arr = _as_u8(buf)
    chunk_bytes = int(chunk_bytes)
    if arr.size == 0:
        return []
    if chunk_bytes % 4:
        # word grid doesn't tile the chunk grid — fall back to per-chunk calls
        return [
            list(digest_bytes(arr[off: off + chunk_bytes]))
            for off in range(0, arr.size, chunk_bytes)
        ]
    n_full = arr.size // chunk_bytes
    out = []
    if n_full:
        body = arr[: n_full * chunk_bytes].view(np.uint32)
        body = body.reshape(n_full, chunk_bytes // 4)
        if use_pallas:
            rows = np.asarray(_rows_checksum(jnp.asarray(body)))
            out.extend([int(a), int(b)] for a, b in rows)
        else:
            out.extend(_rows_checksum_np(body))
    tail = arr[n_full * chunk_bytes:]
    if tail.size:
        out.append(list(digest_bytes(tail)))
    return out
