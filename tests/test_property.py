"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep — skip cleanly when absent
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st
from hypothesis.extra import numpy as hnp

import jax
import jax.numpy as jnp

from repro.core import Box, Checkpoint
from repro.core.env import CraftEnv
from repro.kernels.xor_parity import ops as xor_ops
from repro.train.steps import chunked_cross_entropy, cross_entropy

_SETTINGS = settings(
    max_examples=25, deadline=None,
    suppress_health_check=[HealthCheck.function_scoped_fixture])


# ------------------------------------------------------- checkpoint roundtrip
@_SETTINGS
@given(
    arr=hnp.arrays(
        dtype=st.sampled_from([np.float32, np.float64, np.int32, np.int64,
                               np.uint8, np.bool_]),
        shape=hnp.array_shapes(min_dims=1, max_dims=4, max_side=8),
        elements=st.nothing() | st.just(0),
    ).flatmap(lambda a: st.just(a)),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_ndarray_roundtrip_any_dtype(tmp_path_factory, arr, seed):
    rng = np.random.default_rng(seed)
    if arr.dtype == np.bool_:
        arr = rng.integers(0, 2, arr.shape).astype(np.bool_)
    elif np.issubdtype(arr.dtype, np.integer):
        arr = rng.integers(0, 100, arr.shape).astype(arr.dtype)
    else:
        arr = rng.standard_normal(arr.shape).astype(arr.dtype)
    tmp = tmp_path_factory.mktemp("rt")
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp), "CRAFT_USE_SCR": "0"})
    cp = Checkpoint("p", env=env)
    live = arr.copy()
    cp.add("a", live)
    cp.commit()
    cp.update_and_write()
    blank = np.zeros_like(arr)
    cp2 = Checkpoint("p", env=env)
    cp2.add("a", blank)
    cp2.commit()
    assert cp2.restart_if_needed()
    np.testing.assert_array_equal(blank, arr)


@_SETTINGS
@given(
    leaves=st.lists(
        st.tuples(
            st.sampled_from(["f32", "i32", "bf16"]),
            hnp.array_shapes(min_dims=0, max_dims=3, max_side=6)),
        min_size=1, max_size=5),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_pytree_roundtrip(tmp_path_factory, leaves, seed):
    rng = np.random.default_rng(seed)
    dt = {"f32": jnp.float32, "i32": jnp.int32, "bf16": jnp.bfloat16}
    tree = {
        f"k{i}": jnp.asarray(rng.standard_normal(shape) * 3, dt[kind])
        for i, (kind, shape) in enumerate(leaves)
    }
    tmp = tmp_path_factory.mktemp("pt")
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp), "CRAFT_USE_SCR": "0"})
    box = Box(tree)
    cp = Checkpoint("t", env=env)
    cp.add("t", box)
    cp.commit()
    cp.update_and_write()
    blank = jax.tree_util.tree_map(jnp.zeros_like, tree)
    box2 = Box(blank)
    cp2 = Checkpoint("t", env=env)
    cp2.add("t", box2)
    cp2.commit()
    assert cp2.restart_if_needed()
    for k in tree:
        np.testing.assert_array_equal(
            np.asarray(box2.value[k], np.float32),
            np.asarray(tree[k], np.float32))


# ------------------------------------------------------------- xor parity
@_SETTINGS
@given(
    sizes=st.lists(st.integers(1, 700), min_size=2, max_size=9),
    lost=st.integers(0, 100),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_xor_reconstruct_any_member(sizes, lost, seed):
    rng = np.random.default_rng(seed)
    bufs = [rng.bytes(n) for n in sizes]
    lost = lost % len(bufs)
    parity = xor_ops.parity_of_buffers(bufs)
    survivors = [b for i, b in enumerate(bufs) if i != lost]
    assert xor_ops.reconstruct_member(
        parity, survivors, len(bufs[lost])) == bufs[lost]


# ------------------------------------------------------------ chunked CE
@_SETTINGS
@given(
    b=st.integers(1, 3), l=st.integers(1, 33), v=st.integers(2, 40),
    chunk=st.integers(1, 40), seed=st.integers(0, 2 ** 31 - 1),
)
def test_chunked_ce_equals_full_ce(b, l, v, chunk, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.standard_normal((b, l, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, (b, l)), jnp.int32)
    full = cross_entropy(jnp.einsum("bld,dv->blv", h, w), labels)
    ck = chunked_cross_entropy(
        h, labels, lambda hc: jnp.einsum("bld,dv->blv", hc, w), chunk)
    np.testing.assert_allclose(float(full), float(ck), rtol=1e-5)


# ------------------------------------------------------------ data pipeline
@_SETTINGS
@given(step=st.integers(0, 10_000), seed=st.integers(0, 1000))
def test_data_pipeline_deterministic(step, seed):
    from repro.data.pipeline import SyntheticTokens

    ds = SyntheticTokens(vocab=128, seq_len=16, global_batch=4, seed=seed)
    b1 = ds.batch(step)
    b2 = ds.batch(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 128
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


# --------------------------------------------------------- version counters
@_SETTINGS
@given(freqs=st.lists(st.integers(1, 7), min_size=1, max_size=20))
def test_version_monotonic_under_any_freq_pattern(tmp_path_factory, freqs):
    tmp = tmp_path_factory.mktemp("vm")
    env = CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp), "CRAFT_USE_SCR": "0"})
    b = Box(0)
    cp = Checkpoint("m", env=env)
    cp.add("x", b)
    cp.commit()
    prev = 0
    for i, f in enumerate(freqs, start=1):
        cp.update_and_write(i, f)
        assert cp.version >= prev
        prev = cp.version
    assert cp._pfs.latest_version() == cp.version


# ------------------------------------------------- elastic reshard geometry
@st.composite
def _reshard_case(draw):
    """A global shape, a disjoint source tiling (block decomposition over a
    random axis and rank count), and an arbitrary destination sub-box."""
    gshape = tuple(draw(st.lists(st.integers(1, 8), min_size=1, max_size=3)))
    axis = draw(st.integers(0, len(gshape) - 1))
    nsrc = draw(st.integers(1, 5))
    dst = tuple(
        sorted((draw(st.integers(0, s)), draw(st.integers(0, s))))
        for s in gshape
    )
    return gshape, axis, nsrc, tuple((lo, hi) for lo, hi in dst)


@_SETTINGS
@given(case=_reshard_case(), seed=st.integers(0, 2 ** 31 - 1))
def test_reshard_covers_every_byte_exactly_once(case, seed):
    from repro.core import reshard
    from repro.core.elastic import block_index

    gshape, axis, nsrc, dst = case
    rng = np.random.default_rng(seed)
    src_arr = rng.integers(0, 255, gshape).astype(np.uint8)
    sources = [
        reshard.resolve_index(block_index(gshape, r, nsrc, axis=axis), gshape)
        for r in range(nsrc)
    ]
    # exactly-once: every destination element is written by exactly one run
    counts = np.zeros(reshard.extent_size(dst), dtype=np.int64)
    for src in sources:
        for _, doff, ln in reshard.overlap_runs(src, dst):
            counts[doff:doff + ln] += 1
    assert (counts == 1).all()

    # assembly equals the source array's sub-box
    def open_reader(key):
        ext = key
        block = src_arr[tuple(slice(lo, hi) for lo, hi in ext)]
        flat = np.ascontiguousarray(block).reshape(-1).view(np.uint8)

        class _R:
            def read(self, start, stop):
                return memoryview(flat.tobytes())[start:stop]
        return _R()

    block, covered = reshard.assemble_extent(
        dst, np.uint8, [(s, s) for s in sources], open_reader)
    if covered is not None:
        assert covered.all()
        np.testing.assert_array_equal(
            block, src_arr[tuple(slice(lo, hi) for lo, hi in dst)])


@_SETTINGS
@given(
    payload=st.binary(min_size=0, max_size=200),
    chunk_bytes=st.integers(1, 64),
    codec=st.sampled_from([0, 1]),
    ranges=st.lists(
        st.tuples(st.integers(0, 200), st.integers(0, 200)),
        min_size=1, max_size=6),
)
def test_chunk_range_reader_equals_full_read(
        tmp_path_factory, payload, chunk_bytes, codec, ranges):
    from repro.core.cpbase import IOContext
    from repro.core.storage import ChunkRangeReader, write_array

    tmp = tmp_path_factory.mktemp("crr")
    arr = np.frombuffer(payload, dtype=np.uint8)
    ctx = IOContext(codec_version=codec, chunk_bytes=chunk_bytes)
    path = tmp / "a.bin"
    write_array(path, arr, ctx)
    rdr = ChunkRangeReader(path, ctx)
    assert rdr.nbytes == len(payload)
    for lo, hi in ranges:
        lo, hi = sorted((min(lo, len(payload)), min(hi, len(payload))))
        assert bytes(rdr.read(lo, hi)) == payload[lo:hi]


# ------------------------------------------------------------- adamw
@_SETTINGS
@given(bits=st.sampled_from([32, 8]), seed=st.integers(0, 2 ** 31 - 1))
def test_adamw_moves_against_gradient(bits, seed):
    from repro.optim.adamw import OptimConfig, adamw_init, adamw_update

    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)}
    cfg = OptimConfig(lr=1e-2, state_bits=bits, master_fp32=False,
                      warmup_steps=0, weight_decay=0.0)
    st_ = adamw_init(p, cfg)
    g = {"w": jnp.ones((4, 8), jnp.float32)}
    p2, st2, _ = adamw_update(g, st_, p, cfg)
    # positive gradient → parameters must decrease
    assert float(jnp.mean(p2["w"] - p["w"])) < 0
    assert int(st2["count"]) == 1


# ------------------------------------------------- trace replay / simulator
def _synthetic_trace(count, n_steps, step_s, write_s):
    """A hand-built trace: one config + a fixed-cadence run (no live IO)."""
    env = {"CRAFT_TIER_CHAIN": "pfs", "CRAFT_TIER_EVERY": f"pfs:{count}"}
    events = [{"t": 0.0, "kind": "config", "env": env,
               "payload_bytes": 1 << 20, "comm_size": 1}]
    t, version, ticks = 0.0, 0, 0
    for it in range(n_steps):
        t += step_s
        events.append({"t": t, "kind": "step", "seconds": step_s})
        ticks += 1
        write = ticks % count == 0
        events.append({"t": t, "kind": "decision", "it": it, "cp_freq": 1,
                       "next_version": version + 1, "pending": 0,
                       "write": write, "tiers": ["pfs"] if write else [],
                       "full": False, "sync": False, "final": False,
                       "reason": "cadence" if write else ""})
        if write:
            version += 1
            t += write_s
            events.append({"t": t, "kind": "tier_write", "version": version,
                           "slot": "pfs", "seconds": write_s,
                           "nbytes": 1 << 20, "phys_bytes": 1 << 20,
                           "chunks": 1, "ref_chunks": 0, "full": False})
            events.append({"t": t, "kind": "scheduled", "version": version,
                           "tiers": ["pfs"], "reason": "cadence"})
    return events


@_SETTINGS
@given(
    count=st.integers(1, 9),
    n_steps=st.integers(5, 60),
    step_ms=st.integers(1, 50),
    write_ms=st.integers(1, 200),
)
def test_replay_is_bit_deterministic_and_matches_cadence(
        count, n_steps, step_ms, write_ms):
    """Same trace ⇒ bit-identical re-derived decision sequence, and on a
    clean fixed-cadence trace the replayed policy reproduces the recorded
    decisions exactly (the replay-vs-live contract, minus the live IO)."""
    from repro.core.simulate import replay

    events = _synthetic_trace(count, n_steps, step_ms / 1e3, write_ms / 1e3)
    a = replay(events)
    b = replay(events)
    assert a.sim_decisions == b.sim_decisions          # bit-identical
    assert a.decisions_match, f"diverged at {a.mismatches[:3]}"
    assert a.scheduled_writes == n_steps // count


@_SETTINGS
@given(
    seed=st.integers(0, 2 ** 31 - 1),
    delta=st.floats(1.0, 20.0),
    mtbf=st.floats(100.0, 500.0),
    count=st.integers(1, 64),
)
def test_simulator_deterministic_under_seed(seed, delta, mtbf, count):
    """Same summary + same seed + same config ⇒ identical report; a
    different seed may (and for failure-heavy regimes does) differ."""
    from repro.core.simulate import TraceSummary, simulate_config

    s = TraceSummary(
        config_env={"CRAFT_TIER_CHAIN": "pfs", "CRAFT_TIER_EVERY": "pfs:1",
                    "CRAFT_MTBF_SECONDS": str(mtbf)},
        payload_bytes=1 << 20, comm_size=1, steps=[1.0],
        tier_full_cost={"pfs": delta}, tier_delta_cost={"pfs": delta},
        tier_write_bytes={"pfs": float(1 << 20)}, restore_seconds=delta,
        failure_gaps=[mtbf], duration=1000.0, n_decisions=1000)
    ov = {"CRAFT_TIER_EVERY": f"pfs:{count}"}
    a = simulate_config(s, ov, seed=seed, horizon_steps=300)
    b = simulate_config(s, ov, seed=seed, horizon_steps=300)
    assert a.as_dict() == b.as_dict()


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(
    delta=st.floats(4.0, 12.0),
    mtbf=st.floats(150.0, 300.0),
)
def test_simulator_optimum_agrees_with_daly(delta, mtbf):
    """On a Poisson-failure trace with constant write cost and unit steps,
    the simulator's best fixed interval must sit in the same flat basin as
    Daly's analytic optimum: the overhead at the grid point nearest
    ``daly_interval(δ, M)`` is within 1.5× of the best grid overhead."""
    from repro.core.scheduler import daly_interval
    from repro.core.simulate import TraceSummary, simulate_config

    s = TraceSummary(
        config_env={"CRAFT_TIER_CHAIN": "pfs", "CRAFT_TIER_EVERY": "pfs:1",
                    "CRAFT_MTBF_SECONDS": str(mtbf)},
        payload_bytes=1 << 20, comm_size=1, steps=[1.0],
        tier_full_cost={"pfs": delta}, tier_delta_cost={"pfs": delta},
        tier_write_bytes={"pfs": float(1 << 20)}, restore_seconds=delta,
        failure_gaps=[mtbf], duration=1000.0, n_decisions=1000)
    daly = daly_interval(delta, mtbf)          # seconds == steps (1 s steps)
    grid = sorted({1, 2, 4, 8, 16, 32, 64, 128, 256,
                   max(1, int(round(daly)))})
    horizon = int(6 * mtbf)                    # several expected failures

    def overhead(count):                       # averaged over 3 seeds
        return sum(
            simulate_config(
                s, {"CRAFT_TIER_EVERY": f"pfs:{count}"},
                seed=k, horizon_steps=horizon).overhead_seconds
            for k in (0, 1, 2))

    scores = {n: overhead(n) for n in grid}
    best = min(scores.values())
    nearest = min(grid, key=lambda n: abs(n - daly))
    assert scores[nearest] <= 1.5 * best + 1e-9, (
        f"daly={daly:.1f} nearest={nearest} scores={scores}")
