"""Elastic reshard-on-restore: cross-topology restore matrix (ISSUE 7).

A checkpoint written on N ranks must restore **bit-identically** onto M≠N
ranks, across all three array codecs and all three tiers — each restoring
rank assembling its own block extent from the writers' per-rank chunk grids
(``ShardCp`` + ``reshard.overlap_runs`` + ``storage.ChunkRangeReader``).
Edge leaves ride along on every topology: 0-d scalars (replicated), empty
arrays, unaligned multi-chunk grids, and bfloat16.

All ranks run in one process via ``FakeComm`` (the mem-tier test idiom):
ranks write sequentially into shared storage exactly as SPMD processes
would, then a *different* number of ranks restores.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import Box, Checkpoint, ShardCp
from repro.core.checkpointables import NdArrayCp
from repro.core.elastic import block_index
from repro.core.env import CraftEnv

from tests.test_mem_level import FakeComm


# global source state — the same on every topology; dtype mix covers
# unaligned multi-chunk float32, bf16, 0-d, and empty leaves
_W = (np.arange(19 * 7, dtype=np.float32).reshape(19, 7) * 0.5 + 3.25)
_BF16 = (np.linspace(-4.0, 4.0, 33).astype(jnp.bfloat16))
_SCALAR = np.float64(1234.5678)
_EMPTY = np.empty((0,), dtype=np.float32)


def _env(tmp_path, **extra):
    base = {
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
        "CRAFT_NODE_REDUNDANCY": "LOCAL",
        "CRAFT_TIER_CHAIN": "pfs",
        "CRAFT_MEM_SCRATCH": str(tmp_path / "shm"),
        "CRAFT_CHUNK_BYTES": "64",       # multi-chunk, unaligned grids
        "CRAFT_IO_WORKERS": "1",
    }
    base.update(extra)
    return CraftEnv.capture(base)


def _boxes_for(rank, size):
    """This rank's blocks of the global state (balanced axis-0 split)."""
    w_idx = block_index(_W.shape, rank, size)
    b_idx = block_index(_BF16.shape, rank, size)
    e_idx = block_index(_EMPTY.shape, rank, size)
    return {
        "w": (Box(_W[w_idx].copy()), _W.shape, w_idx),
        "bf16": (Box(np.asarray(_BF16)[b_idx].copy()), _BF16.shape, b_idx),
        "scalar": (Box(np.asarray(_SCALAR).copy()), (), ()),
        "empty": (Box(_EMPTY[e_idx].copy()), _EMPTY.shape, e_idx),
    }


def _build_cp(rank, size, env, zero=False):
    cp = Checkpoint("elastic", FakeComm(rank, size), env=env)
    boxes = {}
    for key, (box, gshape, idx) in _boxes_for(rank, size).items():
        if zero:
            box.value = np.zeros_like(box.value)
        boxes[key] = box
        cp.add(key, ShardCp(box, gshape, idx))
    it = Box(0 if zero else 7)
    boxes["it"] = it
    cp.add("it", it)
    cp.commit()
    return cp, boxes


# Sequential-rank idiom for the shared-staging pfs tier: construct every
# rank's Checkpoint BEFORE anyone writes (rank 0's store ctor sweeps stale
# .tmp dirs), then write rank 0 last — its publish() atomically moves the
# shared staged dir holding every rank's files.  In real SPMD runs the
# barriers inside publish() provide both orderings.
def _ranks_last_leader(n):
    return list(range(1, n)) + [0]


def _write_topology(n, env):
    cps = [_build_cp(rank, n, env) for rank in range(n)]
    for rank in _ranks_last_leader(n):
        assert cps[rank][0].update_and_write()
    for cp, _ in cps:
        cp.close()


def _restore_and_check(m, env, expect_tier=None):
    for rank in range(m):
        cp, boxes = _build_cp(rank, m, env, zero=True)
        assert cp.restart_if_needed()
        if expect_tier is not None:
            assert cp.stats["restore_tier"] == expect_tier
        assert boxes["it"].value == 7
        # bit-identity of every restored block against the global source
        for key, src in (("w", _W), ("bf16", np.asarray(_BF16)),
                         ("empty", _EMPTY)):
            idx = block_index(src.shape, rank, m)
            got = np.asarray(boxes[key].value)
            assert got.dtype == src.dtype, key
            assert got.tobytes() == src[idx].tobytes(), (key, rank, m)
        assert np.asarray(boxes["scalar"].value).tobytes() \
            == np.asarray(_SCALAR).tobytes()
        cp.close()


@pytest.mark.parametrize("n", [1, 2, 4, 8])
@pytest.mark.parametrize("m", [1, 2, 3, 4])
def test_n_to_m_restore_pfs(tmp_path, n, m):
    env = _env(tmp_path)
    _write_topology(n, env)
    _restore_and_check(m, env, expect_tier="pfs")


@pytest.mark.parametrize("codec", [0, 1, 2])
@pytest.mark.parametrize("tier", ["mem", "node", "pfs"])
def test_codec_tier_matrix(tmp_path, codec, tier):
    extra = {"CRAFT_TIER_CHAIN": tier,
             "CRAFT_CODEC_VERSION": str(codec)}
    if codec == 2:
        extra["CRAFT_DELTA"] = "1"
    env = _env(tmp_path, **extra)
    _write_topology(4, env)
    _restore_and_check(3, env, expect_tier=tier)


def test_grow_beyond_writers_node_tier(tmp_path):
    """M > N on the node tier: the new nodes never wrote the version — they
    seed from a peer tree and range-read the rest via aux dirs."""
    env = _env(tmp_path, CRAFT_TIER_CHAIN="node")
    _write_topology(2, env)
    _restore_and_check(4, env, expect_tier="node")


def test_delta_chain_across_three_topologies(tmp_path):
    """A v2 delta version written on topology B whose base was written on
    topology A restores on topology C — refs chase across both layouts."""
    env = _env(tmp_path, CRAFT_DELTA="1")
    rep = np.arange(64, dtype=np.float64)  # rank-replicated, delta-friendly

    def build(rank, size, live, zero_w=False):
        cp = Checkpoint("delta3", FakeComm(rank, size), env=env)
        cp.add("rep", NdArrayCp(live))
        block = _W[block_index(_W.shape, rank, size)]
        box = Box(np.zeros_like(block) if zero_w else block.copy())
        cp.add("w", ShardCp(box, _W.shape, block_index(_W.shape, rank, size)))
        cp.commit()
        return cp, box

    # topology A (N=2): v-1, full write including a replicated array.bin
    cps = [build(rank, 2, rep.copy()) for rank in range(2)]
    for rank in _ranks_last_leader(2):
        assert cps[rank][0].update_and_write()
    for cp, _ in cps:
        cp.close()

    # topology B (M=3): restore v-1 (primes delta state), write v-2 — the
    # unchanged replicated array becomes all-ref chunks against v-1
    cps = [build(rank, 3, rep.copy(), zero_w=True) for rank in range(3)]
    for rank in _ranks_last_leader(3):
        cp, box = cps[rank]
        assert cp.restart_if_needed()
        np.copyto(box.value, _W[block_index(_W.shape, rank, 3)])
        assert cp.update_and_write()
        if rank == 0:
            # the replicated file really is a delta write (chunks skipped)
            assert cp.stats["delta_chunks_skipped"] > 0
    for cp, _ in cps:
        cp.close()

    # topology C (M'=4): restore v-2, chasing refs into the v-1 base that
    # topology A wrote
    for rank in range(4):
        live = np.zeros_like(rep)
        cp, box = build(rank, 4, live, zero_w=True)
        assert cp.restart_if_needed()
        assert cp.version == 2
        assert live.tobytes() == rep.tobytes()
        assert np.asarray(box.value).tobytes() \
            == _W[block_index(_W.shape, rank, 4)].tobytes()
        cp.close()


def test_range_restore_reads_less_than_payload(tmp_path):
    """CRAFT_RESHARD=range: a rank restoring 1/4 of the global array
    physically fetches well under half of the stored payload."""
    big = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    env = _env(tmp_path, CRAFT_RESHARD="range", CRAFT_CHUNK_BYTES="256")

    def build(rank):
        cp = Checkpoint("big", FakeComm(rank, 4), env=env)
        box = Box(big[block_index(big.shape, rank, 4)].copy())
        cp.add("w", ShardCp(box, big.shape, block_index(big.shape, rank, 4)))
        cp.commit()
        return cp

    cps = [build(rank) for rank in range(4)]
    for rank in _ranks_last_leader(4):
        assert cps[rank].update_and_write()
    for cp in cps:
        cp.close()
    idx = block_index(big.shape, 0, 4)
    box = Box(np.zeros_like(big[idx]))
    cp = Checkpoint("big", FakeComm(0, 4), env=env)
    cp.add("w", ShardCp(box, big.shape, idx))
    cp.commit()
    assert cp.restart_if_needed()
    assert np.asarray(box.value).tobytes() == big[idx].tobytes()
    assert 0 < cp.stats["restore_read_bytes"] < big.nbytes // 2
    cp.close()


def test_jax_array_restore_across_topologies(tmp_path):
    """JaxArrayCp manifests written by several ranks reassemble on another
    rank count (single-device extents are full, so coverage overlaps)."""
    src = np.arange(40, dtype=np.float32).reshape(8, 5)
    env = _env(tmp_path)

    def build(rank):
        cp = Checkpoint("jx", FakeComm(rank, 3), env=env)
        cp.add("x", Box(jnp.asarray(src)))
        cp.commit()
        return cp

    cps = [build(rank) for rank in range(3)]
    for rank in _ranks_last_leader(3):
        assert cps[rank].update_and_write()
    for cp in cps:
        cp.close()
    box = Box(jnp.zeros_like(jnp.asarray(src)))
    cp = Checkpoint("jx", FakeComm(0, 2), env=env)
    cp.add("x", box)
    cp.commit()
    assert cp.restart_if_needed()
    assert np.asarray(box.value).tobytes() == src.tobytes()
    cp.close()


def test_nested_invalidation_survives_topology_change(tmp_path):
    """A parent publish on topology A wipes the child from *every* node
    tree, so a later restore on topology B cannot resurrect it."""
    env = _env(tmp_path, CRAFT_TIER_CHAIN="node")
    # child written by both ranks of topology A, then rank 0's parent
    # publishes — which must wipe the child from BOTH node trees
    children = []
    for rank in range(2):
        child = Checkpoint("inner", FakeComm(rank, 2), env=env)
        child.add("it", Box(5))
        child.commit()
        assert child.update_and_write()
        children.append(child)
    parent = Checkpoint("outer", FakeComm(0, 2), env=env)
    parent.add("o", Box(1))
    parent.commit()
    parent.sub_cp(children[0])
    assert parent.update_and_write()   # invalidates the child everywhere
    parent.close()
    for child in children:
        child.close()
    # topology B: nothing of the child is restorable from any node tree
    for rank in range(3):
        child = Checkpoint("inner", FakeComm(rank, 3), env=env)
        child.add("it", Box(0))
        child.commit()
        assert not child.restart_if_needed()
        child.close()
