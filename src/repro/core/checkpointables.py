"""Built-in CRAFT-checkpointable data types (paper §2.2) + extension registry.

Paper default types → JAX analogs:

    POD               → ``Box`` holding int/float/complex/bool/str
    POD array         → ``np.ndarray`` (restored in place)
    POD multi-array   → ``np.ndarray`` (any rank; optional column selection)
    MPI derived type  → pytree of arrays (``PytreeCp``) — the structured-data
                        case; snapshot (``update``) plays the role of MPI_Pack
    CpBase derived    → any user subclass of :class:`repro.core.cpbase.CpBase`

Additionally ``JaxArrayCp`` checkpoints a (possibly sharded) ``jax.Array`` by
saving each addressable shard with its global index — the manifest makes the
file set *topology independent* so a restore may land on a different mesh
(elastic restore, DESIGN.md §2).

The extension mechanism of paper §2.3 (Listing 6's "interface function") is
the :func:`register_adapter` registry: library authors map their type to a
wrapper factory once, after which ``Checkpoint.add()`` works directly on
objects of that type.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Callable, Generic, Optional, TypeVar

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cpbase import CheckpointError, CpBase, IOContext
from repro.core import storage
from repro.core.device_snapshot import DeviceSnapshotter

T = TypeVar("T")


class Box(Generic[T]):
    """Mutable holder — the Python analog of the paper's ``&variable``.

    JAX arrays and Python scalars are immutable, so the library hands out a
    box whose ``.value`` the application reads/writes; ``restart_if_needed``
    restores into the box.
    """

    __slots__ = ("value",)

    def __init__(self, value: T):
        self.value = value

    def __repr__(self) -> str:  # pragma: no cover
        return f"Box({self.value!r})"


# --------------------------------------------------------------------------
# POD
# --------------------------------------------------------------------------
_POD_TYPES = (int, float, complex, bool, str)


class PodCp(CpBase):
    """A single plain-old-data element held in a :class:`Box`."""

    def __init__(self, box: Box):
        if not isinstance(box, Box):
            raise TypeError("PodCp expects a Box")
        self.box = box
        self._buf = box.value

    def update(self) -> None:
        self._buf = self.box.value

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        val = self._buf
        kind = type(val).__name__
        if isinstance(val, complex):
            payload = {"kind": "complex", "re": val.real, "im": val.imag}
        elif isinstance(val, _POD_TYPES):
            payload = {"kind": kind, "value": val}
        else:
            raise CheckpointError(f"not a POD: {type(val)}")
        storage.write_json(dir_path / "pod.json", payload)

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        p = dir_path / "pod.json"
        if not p.exists():
            raise CheckpointError(f"missing {p}")
        payload = storage.read_json(p)
        if payload["kind"] == "complex":
            self.box.value = complex(payload["re"], payload["im"])
        else:
            caster = {"int": int, "float": float, "bool": bool, "str": str}[
                payload["kind"]
            ]
            self.box.value = caster(payload["value"])
        self._buf = self.box.value

    def nbytes(self) -> int:
        return 16


# --------------------------------------------------------------------------
# numpy arrays (POD array / multi-array) — restored IN PLACE like the paper's
# pointer-to-array semantics.
# --------------------------------------------------------------------------
class NdArrayCp(CpBase):
    def __init__(self, arr: np.ndarray, to_cp_col: Optional[int] = None):
        if not isinstance(arr, np.ndarray):
            raise TypeError("NdArrayCp expects np.ndarray")
        self.arr = arr
        self.to_cp_col = to_cp_col  # paper's POD multi-array column selection
        self._buf = self._select().copy()

    def _select(self) -> np.ndarray:
        if self.to_cp_col is None:
            return self.arr
        return self.arr[:, self.to_cp_col]

    def update(self) -> None:
        np.copyto(self._buf, self._select())

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        storage.write_array(dir_path / "array.bin", self._buf, ctx)

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        loaded = storage.read_array(dir_path / "array.bin", ctx)
        target = self._select()
        if loaded.shape != target.shape:
            raise CheckpointError(
                f"shape mismatch: stored {loaded.shape} vs live {target.shape}"
            )
        # no _buf sync here: every write path calls update() first, so the
        # extra copy would only slow the restore hot path down
        target[...] = loaded.astype(target.dtype, copy=False)

    def nbytes(self) -> int:
        return self._buf.nbytes


# --------------------------------------------------------------------------
# jax.Array (possibly sharded) in a Box
# --------------------------------------------------------------------------
def _assign_shard(out: np.ndarray, idx, arr: np.ndarray) -> None:
    """Write a loaded shard into the assembly buffer (rank-0 safe)."""
    if out.ndim == 0:
        out[...] = np.asarray(arr, dtype=out.dtype).reshape(())
    else:
        out[idx] = arr


def _shard_slices(index) -> list:
    """Serialize a shard index (tuple of slices) as [[start, stop], ...]."""
    out = []
    for sl in index:
        out.append([0 if sl.start is None else int(sl.start),
                    None if sl.stop is None else int(sl.stop)])
    return out


class JaxArrayCp(CpBase):
    """Checkpoint a (sharded) ``jax.Array`` held in a Box.

    Write: each *addressable* shard goes to ``shard-<r>-<i>.bin`` (r = process
    rank — paper's process-local file naming) plus ``array.json`` recording the
    global shape/dtype and every shard's global index.  Read: shards are
    assembled into the global array and ``device_put`` onto the sharding of
    the *live* box value — which may differ from the writer's topology
    (elastic restore).
    """

    def __init__(self, box: Box, *, device_snapshot: bool = False,
                 chunk_bytes: Optional[int] = None,
                 device_hist: bool = True):
        if not isinstance(box, Box):
            raise TypeError("JaxArrayCp expects a Box holding a jax.Array")
        self.box = box
        self._buf: list = []     # [(index, np.ndarray, device_meta | None)]
        self._meta: dict = {}
        self._snap = (
            DeviceSnapshotter(chunk_bytes or IOContext.chunk_bytes,
                              with_hist=device_hist)
            if device_snapshot else None
        )
        self.update()

    def update(self) -> None:
        arr = self.box.value
        if not isinstance(arr, jax.Array):
            raise CheckpointError(f"Box no longer holds a jax.Array: {type(arr)}")
        shards = arr.addressable_shards
        if self._snap is not None:
            # Fused device pass per shard: digest + dirty mask + entropy on
            # device, then only the dirty chunks cross to the host mirror.
            self._buf = []
            for i, s in enumerate(shards):
                host, dmeta = self._snap.snapshot(i, s.data)
                self._buf.append((s.index, host, dmeta))
        else:
            # Device→host snapshot of every addressable shard — one batched
            # transfer instead of a blocking per-shard np.asarray.
            hosts = jax.device_get([s.data for s in shards])
            self._buf = [
                (s.index, np.asarray(h), None)
                for s, h in zip(shards, hosts)
            ]
        self._meta = {
            "global_shape": list(arr.shape),
            "dtype": storage._dtype_to_name(arr.dtype),
        }

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        shards_meta = []
        for i, (index, host, dmeta) in enumerate(self._buf):
            fname = f"shard-{ctx.proc_rank}-{i}.bin"
            if dmeta is not None:
                ctx.record_device_meta(
                    storage._manifest_name(dir_path / fname, ctx), dmeta)
            storage.write_array(dir_path / fname, host, ctx)
            shards_meta.append({"file": fname, "index": _shard_slices(index)})
        storage.write_json(
            dir_path / f"array-{ctx.proc_rank}.json",
            {**self._meta, "shards": shards_meta},
        )

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        metas = sorted(dir_path.glob("array-*.json"))
        if not metas:
            raise CheckpointError(f"no array manifest under {dir_path}")
        meta0 = storage.read_json(metas[0])
        gshape = tuple(meta0["global_shape"])
        dtype = storage._dtype_from_name(meta0["dtype"])
        out = np.empty(gshape, dtype=dtype)
        filled = np.zeros(gshape, dtype=bool) if out.size else None
        for mp in metas:
            m = storage.read_json(mp)
            for sh in m["shards"]:
                arr = storage.read_array(dir_path / sh["file"], ctx)
                idx = tuple(
                    slice(s[0], s[1]) for s in sh["index"]
                )
                _assign_shard(out, idx, arr)
                if filled is not None:
                    filled[idx] = True
        if filled is not None and not filled.all():
            raise CheckpointError(
                f"incomplete shard coverage under {dir_path} "
                f"({filled.sum()}/{filled.size} elements)"
            )
        live = self.box.value
        if isinstance(live, jax.Array) and tuple(live.shape) != gshape:
            raise CheckpointError(
                f"shape mismatch: stored {gshape} vs live {tuple(live.shape)}"
            )
        if isinstance(live, jax.Array):
            self.box.value = jax.device_put(out, live.sharding)
        else:  # no live value to infer placement from: single-device put
            self.box.value = jnp.asarray(out)

    def nbytes(self) -> int:
        return sum(h.nbytes for _, h, _ in self._buf)


# --------------------------------------------------------------------------
# pytree of arrays (train states, optimizer states, KV caches, ...)
# --------------------------------------------------------------------------
class PytreeCp(CpBase):
    """Checkpoint an arbitrary pytree held in a Box.

    The tree structure comes from the *live* value at read time (CRAFT
    semantics: state is constructed first, then restored into), so leaves are
    stored by flattened position with shape/dtype validation.  JAX leaves are
    restored onto the live leaf's sharding — restoring onto a different mesh
    reshards transparently.
    """

    def __init__(self, box: Box, *, device_snapshot: bool = False,
                 chunk_bytes: Optional[int] = None,
                 device_hist: bool = True):
        self.box = box
        self._buf: list = []
        self._treedef = None
        self._snap = (
            DeviceSnapshotter(chunk_bytes or IOContext.chunk_bytes,
                              with_hist=device_hist)
            if device_snapshot else None
        )
        self.update()

    def update(self) -> None:
        leaves, treedef = jax.tree_util.tree_flatten(self.box.value)
        self._treedef = treedef
        buf = []
        jax_shards = []      # (buf_item, shard) pairs for one batched D2H
        for i, leaf in enumerate(leaves):
            if isinstance(leaf, jax.Array):
                item = {
                    "kind": "jax",
                    "global_shape": list(leaf.shape),
                    "dtype": storage._dtype_to_name(leaf.dtype),
                    "shards": [],
                }
                for j, s in enumerate(leaf.addressable_shards):
                    if self._snap is not None:
                        host, dmeta = self._snap.snapshot((i, j), s.data)
                        item["shards"].append((s.index, host, dmeta))
                    else:
                        jax_shards.append((item, s))
                buf.append(item)
            elif isinstance(leaf, np.ndarray):
                buf.append({"kind": "np", "data": leaf.copy()})
            else:
                buf.append({"kind": "pod", "data": leaf})
        if jax_shards:
            # One batched device→host transfer for every jax leaf's shards.
            hosts = jax.device_get([s.data for _, s in jax_shards])
            for (item, s), h in zip(jax_shards, hosts):
                item["shards"].append((s.index, np.asarray(h), None))
        self._buf = buf

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        manifest = {"n_leaves": len(self._buf), "leaves": []}
        for i, item in enumerate(self._buf):
            if item["kind"] == "jax":
                shards_meta = []
                for j, (index, host, dmeta) in enumerate(item["shards"]):
                    fname = f"leaf{i}-shard-{ctx.proc_rank}-{j}.bin"
                    if dmeta is not None:
                        ctx.record_device_meta(
                            storage._manifest_name(dir_path / fname, ctx),
                            dmeta)
                    storage.write_array(dir_path / fname, host, ctx)
                    shards_meta.append(
                        {"file": fname, "index": _shard_slices(index)}
                    )
                manifest["leaves"].append(
                    {
                        "kind": "jax",
                        "global_shape": item["global_shape"],
                        "dtype": item["dtype"],
                        "shards": shards_meta,
                    }
                )
            elif item["kind"] == "np":
                fname = f"leaf{i}.bin"
                storage.write_array(dir_path / fname, item["data"], ctx)
                manifest["leaves"].append({"kind": "np", "file": fname})
            else:
                manifest["leaves"].append(
                    {"kind": "pod", "value": _pod_json(item["data"])}
                )
        storage.write_json(dir_path / f"tree-{ctx.proc_rank}.json", manifest)

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        metas = sorted(dir_path.glob("tree-*.json"))
        if not metas:
            raise CheckpointError(f"no pytree manifest under {dir_path}")
        # parse every writer's manifest once up front — the per-leaf shard
        # merge below would otherwise re-parse them per leaf (O(leaves²))
        parsed = [storage.read_json(mp) for mp in metas]
        manifest = parsed[0]
        live_leaves, treedef = jax.tree_util.tree_flatten(self.box.value)
        if manifest["n_leaves"] != len(live_leaves):
            raise CheckpointError(
                f"pytree leaf count mismatch: stored {manifest['n_leaves']} "
                f"vs live {len(live_leaves)}"
            )
        new_leaves = []
        for i, (spec, live) in enumerate(zip(manifest["leaves"], live_leaves)):
            if spec["kind"] == "jax":
                gshape = tuple(spec["global_shape"])
                dtype = storage._dtype_from_name(spec["dtype"])
                out = np.empty(gshape, dtype=dtype)
                for m in parsed:  # merge shard sets from all writer procs
                    for sh in m["leaves"][i].get("shards", []):
                        arr = storage.read_array(dir_path / sh["file"], ctx)
                        idx = tuple(slice(s[0], s[1]) for s in sh["index"])
                        _assign_shard(out, idx, arr)
                if isinstance(live, jax.Array):
                    if tuple(live.shape) != gshape:
                        raise CheckpointError(
                            f"leaf {i} shape mismatch {gshape} vs {live.shape}"
                        )
                    new_leaves.append(jax.device_put(out, live.sharding))
                else:
                    new_leaves.append(jnp.asarray(out))
            elif spec["kind"] == "np":
                arr = storage.read_array(dir_path / spec["file"], ctx)
                # memory-tier reads hand out read-only views of shared
                # buffers; a tree leaf is owned by the application, so copy
                new_leaves.append(arr if arr.flags.writeable else arr.copy())
            else:
                new_leaves.append(_pod_unjson(spec["value"]))
        self.box.value = jax.tree_util.tree_unflatten(treedef, new_leaves)

    def nbytes(self) -> int:
        total = 0
        for item in self._buf:
            if item["kind"] == "jax":
                total += sum(h.nbytes for _, h, _ in item["shards"])
            elif item["kind"] == "np":
                total += item["data"].nbytes
        return total


def _pod_json(v):
    if isinstance(v, complex):
        return {"kind": "complex", "re": v.real, "im": v.imag}
    return {"kind": type(v).__name__, "value": v}


def _pod_unjson(d):
    if d["kind"] == "complex":
        return complex(d["re"], d["im"])
    return {"int": int, "float": float, "bool": bool, "str": str, "NoneType": lambda v: None}[
        d["kind"]
    ](d.get("value"))


# --------------------------------------------------------------------------
# getter/setter adapter (for data not reachable via a Box, e.g. an object
# attribute or a library handle)
# --------------------------------------------------------------------------
class FuncCp(CpBase):
    def __init__(self, get: Callable[[], Any], set_: Callable[[Any], None]):
        self._get, self._set = get, set_
        self._inner: Optional[CpBase] = None
        self._box = Box(None)
        self.update()

    def _wrap(self, value) -> CpBase:
        self._box.value = value
        if isinstance(value, jax.Array):
            return JaxArrayCp(self._box)
        if isinstance(value, np.ndarray):
            return NdArrayCp(value)
        if isinstance(value, _POD_TYPES):
            return PodCp(self._box)
        return PytreeCp(self._box)

    def update(self) -> None:
        self._inner = self._wrap(self._get())
        self._inner.update()

    def write(self, dir_path: Path, ctx: IOContext) -> None:
        assert self._inner is not None
        self._inner.write(dir_path, ctx)

    def read(self, dir_path: Path, ctx: IOContext) -> None:
        assert self._inner is not None
        self._inner.read(dir_path, ctx)
        self._set(self._box.value)

    def nbytes(self) -> int:
        return self._inner.nbytes() if self._inner else 0


# --------------------------------------------------------------------------
# extension registry (paper §2.3, Listing 6)
# --------------------------------------------------------------------------
_ADAPTERS: list = []   # [(predicate, factory)]


def register_adapter(predicate: Callable[[Any], bool],
                     factory: Callable[[Any], CpBase]) -> None:
    """Register an ``add()`` adapter for a user/library data type.

    ``predicate(obj)`` decides applicability; ``factory(obj)`` returns the
    checkpointable wrapper.  This is the paper's "interface function inside
    CRAFT" (Listing 6) — after registration, end users can pass their objects
    straight to ``Checkpoint.add()``.
    """
    _ADAPTERS.append((predicate, factory))


def wrap(obj: Any, **kw) -> CpBase:
    """Dispatch an ``add()`` argument to a checkpointable (paper's overloads)."""
    if isinstance(obj, CpBase):
        return obj
    for predicate, factory in _ADAPTERS:
        if predicate(obj):
            return factory(obj)
    if isinstance(obj, Box):
        v = obj.value
        snap_kw = {
            "device_snapshot": kw.get("device_snapshot", False),
            "chunk_bytes": kw.get("chunk_bytes"),
            "device_hist": kw.get("device_hist", True),
        }
        if isinstance(v, jax.Array):
            return JaxArrayCp(obj, **snap_kw)
        if isinstance(v, _POD_TYPES):
            return PodCp(obj)
        return PytreeCp(obj, **snap_kw)
    if isinstance(obj, np.ndarray):
        return NdArrayCp(obj, to_cp_col=kw.get("to_cp_col"))
    if isinstance(obj, jax.Array):
        raise TypeError(
            "jax.Array is immutable — wrap it in repro.core.Box(arr) so the "
            "restored value can be handed back (paper's &ptr analog)"
        )
    if isinstance(obj, _POD_TYPES):
        raise TypeError(
            f"{type(obj).__name__} is immutable — wrap it in repro.core.Box(x)"
        )
    raise TypeError(
        f"don't know how to checkpoint {type(obj)}; subclass CpBase or "
        "register_adapter() it (paper §2.3)"
    )
