"""Pure-jnp oracle for blocked (flash) attention.

Plain materialized-scores attention with fp32 softmax. Supports:
  * GQA — ``Hq`` a multiple of ``Hkv`` (query heads grouped over kv heads),
  * causal masking with a query position offset (decode / chunked prefill),
  * sliding-window attention (h2o-danube's SWA) — key positions in
    ``(q_pos - window, q_pos]``,
  * a ``kv_len`` bound so padded key slots never attend.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def attention_ref(
    q: jnp.ndarray,                    # (B, Hq, Lq, Dqk)
    k: jnp.ndarray,                    # (B, Hkv, Lk, Dqk)
    v: jnp.ndarray,                    # (B, Hkv, Lk, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,
    kv_len: Optional[int] = None,
) -> jnp.ndarray:
    b, hq, lq, dqk = q.shape
    _, hkv, lk, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    if sm_scale is None:
        sm_scale = dqk ** -0.5
    # (B, Hkv, G, Lq, Lk)
    qg = q.reshape(b, hkv, group, lq, dqk)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    qpos = q_offset + jnp.arange(lq)[:, None]          # (Lq, 1)
    kpos = jnp.arange(lk)[None, :]                     # (1, Lk)
    mask = jnp.ones((lq, lk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(denom > 0, p / jnp.where(denom == 0, 1.0, denom), 0.0)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, lq, v.shape[-1]).astype(q.dtype)
