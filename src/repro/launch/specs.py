"""Input/state ShapeDtypeStruct builders for the multi-pod dry-run.

``input_specs(arch, shape)`` (and the ``build_*`` step builders below)
return weak-type-correct, *sharded* ``jax.ShapeDtypeStruct`` stand-ins for
every model input — no device allocation ever happens; the full-size
configs are exercised exclusively through ``jit(...).lower(...).compile()``.

Three step kinds map to the assigned shape kinds:

    train_4k      → ``train_step(params, opt_state, batch)``
    prefill_32k   → ``prefill(params, tokens[, embeds])``
    decode_32k /
    long_500k     → ``decode(params, cache, tokens (B,1), pos)``

Audio/VLM archs get a modality-stub ``embeds`` prefix of ``cfg.n_patches``
frames/patches (the frontend is a stub per the assignment); the text/token
span shrinks so the total sequence stays at the assigned ``seq_len``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs import ShapeSpec, get_config
from repro.models import model as M
from repro.models.common import ModelConfig
from repro.optim.adamw import OptimConfig, adamw_init, opt_state_logical
from repro.sharding.activations import use_rules
from repro.sharding.logical import LogicalRules, shard_specs
from repro.train.steps import (
    TrainStepConfig, make_decode_step, make_prefill, make_train_step,
)


def _named(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def _sds(shape_tree, sharding_tree):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shape_tree, sharding_tree)


def arch_config(arch_id: str, mesh: Mesh, tiny: bool = False) -> ModelConfig:
    """Arch config adjusted for the mesh's tensor-parallel degree."""
    cfg = get_config(arch_id, tiny=tiny)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return cfg.padded_for_tp(axis_sizes.get("model", 1))


def dryrun_opt(cfg: ModelConfig) -> OptimConfig:
    """Per-arch optimizer policy: ≥100B params → 8-bit moments, no master
    copy (the difference between fitting and not fitting the MoE cells on a
    16 GB v5e — see EXPERIMENTS.md §Dry-run)."""
    big = cfg.param_count() > 100e9
    return OptimConfig(state_bits=8 if big else 32, master_fp32=False)


@dataclasses.dataclass
class BuiltStep:
    """A lowered-ready step: ``jit_fn.lower(*args)`` is all that's left."""
    fn: object                  # the pure step function
    args: tuple                 # sharded ShapeDtypeStruct inputs
    out_shardings: object
    donate_argnums: tuple
    cfg: ModelConfig
    rules: object = None        # LogicalRules for activation constraints

    def lower(self, mesh: Mesh):
        jitted = jax.jit(self.fn, out_shardings=self.out_shardings,
                         donate_argnums=self.donate_argnums)
        # activation constraints (sharding.activations) apply during trace
        rules = self.rules if self.rules is not None else LogicalRules(mesh)
        with jax.set_mesh(mesh), use_rules(rules):
            return jitted.lower(*self.args)


# ------------------------------------------------------------------ batch
def input_specs(cfg: ModelConfig, shape: ShapeSpec, rules: LogicalRules,
                mesh: Mesh) -> dict:
    """Training/prefill token batch as sharded ShapeDtypeStructs."""
    b, l = shape.global_batch, shape.seq_len
    n_stub = cfg.n_patches if cfg.frontend else 0
    l_tok = l - n_stub
    out = {
        "tokens": jax.ShapeDtypeStruct(
            (b, l_tok), jnp.int32,
            sharding=NamedSharding(
                mesh, rules.spec("batch", "seq", shape=(b, l_tok)))),
        "labels": jax.ShapeDtypeStruct(
            (b, l_tok), jnp.int32,
            sharding=NamedSharding(
                mesh, rules.spec("batch", "seq", shape=(b, l_tok)))),
    }
    if n_stub:
        out["embeds"] = jax.ShapeDtypeStruct(
            (b, n_stub, cfg.d_model), cfg.dtype,
            sharding=NamedSharding(
                mesh, rules.spec("batch", "patches", "embed_act",
                                 shape=(b, n_stub, cfg.d_model))))
    return out


def param_specs(cfg: ModelConfig, rules: LogicalRules, mesh: Mesh):
    shapes = jax.eval_shape(
        lambda k: M.init_params(k, cfg), jax.random.key(0))
    specs = shard_specs(rules, M.param_logical(cfg), shapes)
    return _sds(shapes, _named(mesh, specs)), specs


def opt_specs(cfg: ModelConfig, ocfg: OptimConfig, param_sds,
              rules: LogicalRules, mesh: Mesh):
    shapes = jax.eval_shape(lambda p: adamw_init(p, ocfg), param_sds)
    logical = opt_state_logical(M.param_logical(cfg), ocfg, params=param_sds)
    specs = shard_specs(rules, logical, shapes)
    return _sds(shapes, _named(mesh, specs)), specs


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                rules: LogicalRules, mesh: Mesh):
    shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, batch, max_len))
    specs = shard_specs(rules, M.cache_logical(cfg), shapes)
    return _sds(shapes, _named(mesh, specs)), specs


# ------------------------------------------------------------- step builders
def train_rules(mesh: Mesh, sequence_parallel: bool = True,
                profile: str = "tp2d") -> LogicalRules:
    """Training parallelism profiles (EXPERIMENTS.md §Perf iteration 2.1).

    ``tp2d`` — MaxText-style 2-D: batch over (pod, data), weights FSDP over
    data × TP over model.  With ``sequence_parallel`` the residual stream's
    d_model shards over the model axis (Megatron-SP): same collective wire
    bytes as TP all-reduce, but saved-for-backward residuals shrink by the
    TP degree — the difference between fitting and not fitting the 61-layer
    archs in HBM.

    ``fsdp`` — pure ZeRO-3: batch over (pod, data, **model**) — one sequence
    per chip at train_4k — and weights sharded over (data, model); layer
    weights are all-gathered on use.  For the ≤10B dense/SSM archs the 2-D
    profile is dominated by TP collectives that scale with *activations*
    (≈630 GB/device/step for falcon-7b), while FSDP's collectives scale
    with *weights* (≈3 passes × params/device ≈ 50 GB): ~10× less wire.
    MoE archs keep ``tp2d`` (experts need the model axis for EP).
    """
    rules = LogicalRules(mesh)
    if profile == "fsdp":
        rules.rules.update({
            "batch": ("pod", "data", "model"),
            "embed": ("data", "model"),
            "heads": None, "kv_heads": None, "mlp": None, "vocab": "model",
            "ssm_inner": None, "ssm_heads": None, "latent": None,
            "embed_act": None,
        })
        return rules
    if sequence_parallel:
        rules.rules["embed_act"] = "model"
    return rules


def train_profile(cfg: ModelConfig) -> str:
    """Default profile per arch family: MoE keeps 2-D (EP needs the model
    axis); dense/SSM/hybrid train pure-FSDP (§Perf iteration 2.1)."""
    return "tp2d" if cfg.n_experts else "fsdp"


def build_train(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                ocfg: Optional[OptimConfig] = None,
                scfg: Optional[TrainStepConfig] = None,
                sequence_parallel: bool = True,
                profile: Optional[str] = None) -> BuiltStep:
    rules = train_rules(mesh, sequence_parallel,
                        profile or train_profile(cfg))
    ocfg = ocfg or dryrun_opt(cfg)
    # bf16 gradients halve the DP-reduction wire bytes (compressed-DP) and
    # 512-token CE chunks cut the per-chunk unembed weight-gather/grad-
    # reduce count 4x vs the 128 default (§Perf iteration 2.2)
    scfg = scfg or TrainStepConfig(grad_dtype="bfloat16", loss_chunk=512)
    p_sds, p_specs = param_specs(cfg, rules, mesh)
    o_sds, o_specs = opt_specs(cfg, ocfg, p_sds, rules, mesh)
    batch = input_specs(cfg, shape, rules, mesh)
    step = make_train_step(cfg, ocfg, scfg)
    return BuiltStep(
        fn=step,
        args=(p_sds, o_sds, batch),
        out_shardings=(_named(mesh, p_specs), _named(mesh, o_specs), None),
        donate_argnums=(0, 1),
        cfg=cfg,
        rules=rules,
    )


def build_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> BuiltStep:
    rules = LogicalRules(mesh)
    p_sds, p_specs = param_specs(cfg, rules, mesh)
    batch = input_specs(cfg, shape, rules, mesh)
    _, c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len,
                             rules, mesh)
    fn = make_prefill(cfg, shape.global_batch, shape.seq_len)
    args = (p_sds, batch["tokens"])
    if "embeds" in batch:
        args = args + (batch["embeds"],)
    return BuiltStep(
        fn=fn, args=args,
        out_shardings=(_named(mesh, c_specs), None),
        donate_argnums=(),
        cfg=cfg,
        rules=rules,
    )


def build_decode(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh) -> BuiltStep:
    rules = LogicalRules(mesh)
    p_sds, p_specs = param_specs(cfg, rules, mesh)
    c_sds, c_specs = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                 rules, mesh)
    b = shape.global_batch
    tokens = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32,
        sharding=NamedSharding(mesh, rules.spec("batch", None, shape=(b, 1))))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(
                                   mesh, jax.sharding.PartitionSpec()))
    fn = make_decode_step(cfg)
    return BuiltStep(
        fn=fn, args=(p_sds, c_sds, tokens, pos),
        out_shardings=(_named(mesh, c_specs), None),
        donate_argnums=(1,),          # cache is updated in place
        cfg=cfg,
        rules=rules,
    )


def build_step(arch_id: str, shape: ShapeSpec, mesh: Mesh,
               tiny: bool = False) -> BuiltStep:
    cfg = arch_config(arch_id, mesh, tiny=tiny)
    if shape.kind == "train":
        return build_train(cfg, shape, mesh)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh)
    return build_decode(cfg, shape, mesh)
