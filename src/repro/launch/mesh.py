"""Production mesh definitions (single-pod 16×16, multi-pod 2×16×16).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import and only then builds the mesh.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / small runs (e.g. ((1,), ('data',)))."""
    return jax.make_mesh(tuple(shape), tuple(axes))
