"""Deterministic trace replay + what-if simulation — the *replay* third of
the record → replay → tune loop (``core/trace.py`` records, ``core/tune.py``
searches).

Two modes, both driving the **real** :class:`~repro.core.scheduler.
CheckpointPolicy` (never a re-implementation of its rules — a fork would
drift the first time the scheduler learns a trick the simulator doesn't):

* :func:`replay` — **exact replay**: walk a recorded trace in order, set a
  fake clock to each event's recorded timestamp, feed the policy the exact
  inputs the live run saw (iteration, ``cp_freq``, writer backpressure,
  landed tier writes, degraded routings, restores, recovery resets) and
  re-derive every decision.  Because count cadences and the recorded-input
  reconstruction are fully deterministic, the simulated decision sequence
  must equal the recorded one bit for bit — ``tests/test_simulate.py``
  asserts exactly that against a live chaos run.

* :func:`simulate_config` — **what-if**: summarize the trace into empirical
  distributions (step durations, per-tier full/delta write costs, restore
  cost, failure inter-arrivals) and run a seeded discrete-event loop over a
  *candidate* config, reporting expected overhead
  ``write + rework-after-failure + restore``.  No wall clock, no global
  RNG: same trace + same seed + same config ⇒ identical report
  (``tests/test_property.py`` holds the line).

Cost scaling for configs the trace never ran: redundancy knobs scale the
measured per-tier costs analytically — Reed-Solomon parity ``m`` over ``k``
data shards amplifies writes by ``(k+m)/k``, ``R`` RAM replicas by
``1+R``, and a delta chain of depth ``D`` pays one full write per ``D``
versions (``(full + (D-1)·delta)/D``).  Everything else (cadences,
intervals) goes through the real policy.
"""
from __future__ import annotations

import dataclasses
import json
import math
import random
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.core.env import CraftEnv
from repro.core.scheduler import (
    DEFAULT_MTBF_SECONDS, CheckpointPolicy, Decision,
)
from repro.core.tiers import StorageTier

__all__ = [
    "load_trace", "summarize", "replay", "simulate_config",
    "TraceSummary", "ReplayReport", "SimReport", "FakeClock", "SimTier",
]


def load_trace(path) -> List[dict]:
    """Parse a JSONL trace; skips blank and torn (truncated) lines — a
    killed run's last line may be partial, which is normal, not an error."""
    events = []
    with open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                continue          # torn tail line from a killed writer
            if isinstance(ev, dict) and "kind" in ev:
                events.append(ev)
    return events


class FakeClock:
    """Injectable monotonic clock: ``clock()`` returns ``t``; the replayer
    pins it to recorded timestamps, the what-if loop advances it."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


class SimTier(StorageTier):
    """Cost-model-only tier: the policy reads ``write_cost()`` off the base
    class; nothing here ever touches a filesystem."""

    def __init__(self, slot: str):
        self.label = slot

    def stage(self, version):
        raise NotImplementedError("SimTier carries costs, not data")

    def publish(self, staged, version, extra_meta=None):
        raise NotImplementedError

    def abort(self, staged):
        raise NotImplementedError

    def latest_version(self) -> int:
        return 0

    def version_dir(self, version):
        return Path("/nonexistent") / f"v-{version}"

    def invalidate_all(self) -> None:
        pass


# ---------------------------------------------------------------------------
# exact replay
# ---------------------------------------------------------------------------
_DECISION_FIELDS = ("write", "tiers", "full", "sync", "final", "reason")


def _normalize(d) -> Tuple:
    """A Decision (or a recorded decision event) as a comparable tuple."""
    if isinstance(d, Decision):
        return (d.write, tuple(d.tiers), d.full, d.sync, d.final, d.reason)
    return (bool(d.get("write")), tuple(d.get("tiers") or ()),
            bool(d.get("full")), bool(d.get("sync")), bool(d.get("final")),
            str(d.get("reason", "")))


@dataclasses.dataclass
class ReplayReport:
    """Exact-replay outcome: the re-derived decision sequence next to the
    recorded one, plus the policy-side write accounting the live
    ``Checkpoint.stats`` must agree with."""

    sim_decisions: List[Tuple]
    recorded_decisions: List[Tuple]
    mismatches: List[int]                 # indices where the two differ
    scheduled_writes: int                 # write=True decisions re-derived
    tier_scheduled: Dict[str, int]        # slot -> scheduled (pre-fault)
    tier_landed: Dict[str, int]           # slot -> landed (from the trace)
    tier_landed_bytes: Dict[str, int]
    full_writes: int                      # re-derived full (non-delta) writes
    config_env: Dict[str, str]

    @property
    def decisions_match(self) -> bool:
        return (not self.mismatches
                and len(self.sim_decisions) == len(self.recorded_decisions))


def replay(events: List[dict],
           env_overrides: Optional[dict] = None) -> ReplayReport:
    """Re-derive every recorded decision through a fresh, real policy.

    External inputs (what the world did) come from the trace; internal
    state (what the policy decides) is recomputed.  ``env_overrides``
    patches the recorded config snapshot — with overrides the decision
    sequences legitimately diverge; without them they must match.
    """
    cfg = next((e for e in events if e["kind"] == "config"), None)
    if cfg is None:
        raise ValueError("trace has no config event — nothing to replay")
    envmap = {"CRAFT_CP_PATH": "/unused", **cfg["env"],
              **(env_overrides or {})}
    env = CraftEnv.capture(envmap)
    clock = FakeClock(float(cfg.get("t", 0.0)))
    stores = {slot: SimTier(slot) for slot in env.tier_chain}
    pending = [0]
    policy = CheckpointPolicy(env, stores, clock=clock,
                              backpressure=lambda: pending[0])
    sim: List[Tuple] = []
    rec: List[Tuple] = []
    mismatches: List[int] = []
    tier_scheduled: Dict[str, int] = {s: 0 for s in env.tier_chain}
    tier_landed: Dict[str, int] = {s: 0 for s in env.tier_chain}
    tier_landed_bytes: Dict[str, int] = {s: 0 for s in env.tier_chain}
    full_writes = 0
    last_write_decision: Optional[Decision] = None

    for ev in events:
        kind = ev["kind"]
        clock.t = float(ev.get("t", clock.t))
        if kind == "decision":
            pending[0] = int(ev.get("pending", 0))
            d = policy.need_checkpoint(
                ev.get("it"), int(ev.get("cp_freq", 1)),
                next_version=int(ev.get("next_version", 1)))
            sim.append(_normalize(d))
            rec.append(_normalize(ev))
            if sim[-1] != rec[-1]:
                mismatches.append(len(sim) - 1)
            if d.write:
                last_write_decision = d
                for slot in d.tiers:
                    tier_scheduled[slot] = tier_scheduled.get(slot, 0) + 1
                if d.full:
                    full_writes += 1
        elif kind == "scheduled":
            d = last_write_decision
            if d is None or not d.write:
                # replay diverged (overrides) — reconstruct from the record
                d = Decision(write=True, tiers=tuple(ev.get("tiers", ())),
                             reason=str(ev.get("reason", "")))
            policy.record_written(d, int(ev["version"]))
            last_write_decision = None
        elif kind == "step":
            policy.observe_step_seconds(float(ev["seconds"]))
        elif kind == "tier_write":
            slot = ev["slot"]
            store = stores.get(slot)
            if store is not None:
                store.record_write(float(ev.get("seconds", 0.0)),
                                   int(ev.get("nbytes", 0)))
            policy.note_tier_written(slot)
            tier_landed[slot] = tier_landed.get(slot, 0) + 1
            tier_landed_bytes[slot] = (
                tier_landed_bytes.get(slot, 0) + int(ev.get("nbytes", 0)))
        elif kind == "degraded":
            policy.note_degraded(ev["slot"])
        elif kind == "restore":
            policy.notify_restore()
        elif kind == "recovery":
            policy.reset_estimators()
        # config (first consumed above), tier_cost (duplicate of
        # tier_write), breaker/failure/kill/retune: no policy-side input

    return ReplayReport(
        sim_decisions=sim, recorded_decisions=rec, mismatches=mismatches,
        scheduled_writes=sum(1 for d in sim if d[0]),
        tier_scheduled=tier_scheduled, tier_landed=tier_landed,
        tier_landed_bytes=tier_landed_bytes, full_writes=full_writes,
        config_env=dict(cfg["env"]),
    )


# ---------------------------------------------------------------------------
# trace summary (the what-if simulator's empirical inputs)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class TraceSummary:
    """Empirical distributions distilled from one trace."""

    config_env: Dict[str, str]
    payload_bytes: int
    comm_size: int
    steps: List[float]                    # observed step durations (seconds)
    tier_full_cost: Dict[str, float]      # slot -> mean full-write seconds
    tier_delta_cost: Dict[str, float]     # slot -> mean delta-write seconds
    tier_write_bytes: Dict[str, float]    # slot -> mean logical bytes
    restore_seconds: Optional[float]      # mean restore latency (None: none)
    failure_gaps: List[float]             # inter-arrival seconds of failures
    duration: float                       # trace wall span (seconds)
    n_decisions: int

    def mtbf(self) -> float:
        """Empirical MTBF from the failure stream, else the configured
        ``CRAFT_MTBF_SECONDS``, else the scheduler's 1-day default."""
        if self.failure_gaps:
            return max(1e-6, sum(self.failure_gaps) / len(self.failure_gaps))
        cfg = float(self.config_env.get("CRAFT_MTBF_SECONDS", "0") or 0)
        if cfg > 0:
            return cfg
        return DEFAULT_MTBF_SECONDS

    def mean_step(self) -> float:
        if self.steps:
            return sum(self.steps) / len(self.steps)
        if self.n_decisions > 0 and self.duration > 0:
            return self.duration / self.n_decisions
        return 1.0


def _mean(xs: List[float]) -> Optional[float]:
    return sum(xs) / len(xs) if xs else None


def summarize(events: List[dict]) -> TraceSummary:
    cfg = next((e for e in events if e["kind"] == "config"), None)
    if cfg is None:
        raise ValueError("trace has no config event — nothing to summarize")
    steps: List[float] = []
    gap_steps: List[float] = []     # fallback when no step events exist
    full_costs: Dict[str, List[float]] = {}
    delta_costs: Dict[str, List[float]] = {}
    wbytes: Dict[str, List[float]] = {}
    restores: List[float] = []
    fail_ts: List[float] = []
    n_decisions = 0
    t_min = t_max = float(cfg.get("t", 0.0))
    prev_decision_t: Optional[float] = None
    prev_decision_it = object()
    for ev in events:
        t = float(ev.get("t", 0.0))
        t_min, t_max = min(t_min, t), max(t_max, t)
        kind = ev["kind"]
        if kind == "step":
            steps.append(float(ev["seconds"]))
        elif kind == "decision":
            n_decisions += 1
            it = ev.get("it")
            if prev_decision_t is not None and it != prev_decision_it:
                gap = t - prev_decision_t
                if gap > 0:
                    gap_steps.append(gap)
            prev_decision_t, prev_decision_it = t, it
        elif kind == "tier_write":
            slot = ev["slot"]
            bucket = full_costs if ev.get("full") else delta_costs
            bucket.setdefault(slot, []).append(float(ev.get("seconds", 0.0)))
            wbytes.setdefault(slot, []).append(float(ev.get("nbytes", 0)))
        elif kind == "restore":
            restores.append(float(ev.get("seconds", 0.0)))
        elif kind in ("failure", "kill"):
            fail_ts.append(t)
    # a tier that only ever wrote one flavor still needs both cost models:
    # borrow the observed flavor (delta ≈ full is conservative for tuning)
    slots = set(full_costs) | set(delta_costs)
    tier_full = {}
    tier_delta = {}
    for slot in slots:
        f = _mean(full_costs.get(slot, []))
        d = _mean(delta_costs.get(slot, []))
        tier_full[slot] = f if f is not None else d
        tier_delta[slot] = d if d is not None else f
    gaps = [b - a for a, b in zip(fail_ts, fail_ts[1:]) if b > a]
    if fail_ts and not gaps and t_max > fail_ts[0]:
        gaps = [max(1e-6, t_max - t_min)]     # one failure over the span
    return TraceSummary(
        config_env=dict(cfg["env"]),
        payload_bytes=int(cfg.get("payload_bytes", 0)),
        comm_size=int(cfg.get("comm_size", 1)),
        steps=steps or gap_steps,
        tier_full_cost=tier_full,
        tier_delta_cost=tier_delta,
        tier_write_bytes={s: _mean(v) or 0.0 for s, v in wbytes.items()},
        restore_seconds=_mean(restores),
        failure_gaps=gaps,
        duration=max(0.0, t_max - t_min),
        n_decisions=n_decisions,
    )


# ---------------------------------------------------------------------------
# what-if simulation
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SimReport:
    """Expected-overhead scorecard for one candidate config."""

    overrides: Dict[str, str]             # CRAFT_* patches vs the trace
    horizon_steps: int
    seed: int
    useful_seconds: float                 # pure compute simulated
    write_seconds: float
    rework_seconds: float                 # lost compute re-done after failures
    restore_seconds: float
    failures: int
    writes: int
    tier_writes: Dict[str, int]

    @property
    def overhead_seconds(self) -> float:
        return self.write_seconds + self.rework_seconds + self.restore_seconds

    @property
    def overhead_fraction(self) -> float:
        return self.overhead_seconds / max(1e-9, self.useful_seconds)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["overhead_seconds"] = round(self.overhead_seconds, 6)
        d["overhead_fraction"] = round(self.overhead_fraction, 6)
        return d


def _cost_scale(env: CraftEnv, base: CraftEnv, slot: str) -> float:
    """Analytic write-cost scale for redundancy knobs vs the as-run config."""
    scale = 1.0
    if slot == "mem":
        scale *= (1.0 + env.mem_replicas) / (1.0 + base.mem_replicas)
    elif slot == "node" and base.node_redundancy.upper() == "RS":
        k = max(1, base.xor_group_size)
        scale *= (k + env.rs_parity) / (k + max(0, base.rs_parity))
    return scale


def simulate_config(summary: TraceSummary,
                    overrides: Optional[dict] = None,
                    *,
                    seed: int = 0,
                    horizon_steps: Optional[int] = None) -> SimReport:
    """Expected overhead of ``overrides`` applied to the recorded config.

    A seeded discrete-event loop drives the real policy step by step on a
    fake clock: compute a step, ask ``need_checkpoint``, pay the modeled
    per-tier write cost for every scheduled tier, and on each sampled
    failure pay the rework (compute since the last completed checkpoint)
    plus a restore.  Deterministic by construction — the only randomness is
    ``random.Random(seed)`` driving the failure inter-arrivals.
    """
    overrides = dict(overrides or {})
    base_env = CraftEnv.capture(
        {"CRAFT_CP_PATH": "/unused", **summary.config_env})
    env = CraftEnv.capture(
        {"CRAFT_CP_PATH": "/unused", **summary.config_env, **overrides})
    if horizon_steps is None:
        horizon_steps = max(1000, 2 * len(summary.steps))
    steps = summary.steps or [summary.mean_step()]
    mtbf = summary.mtbf()

    delta_on = env.delta
    depth = max(1, env.delta_max_chain)

    def tier_cost(slot: str, full: bool) -> float:
        f = summary.tier_full_cost.get(slot)
        d = summary.tier_delta_cost.get(slot)
        if f is None and d is None:
            # never observed (e.g. a breaker kept it dark): model it from
            # the payload at a conservative 200 MB/s, floored at 1 ms
            f = d = max(1e-3, summary.payload_bytes / 200e6)
        scale = _cost_scale(env, base_env, slot)
        if full or not delta_on or slot == "mem":
            return (f if f is not None else d) * scale
        # a depth-D chain pays one full write per D versions on average
        return ((f + (depth - 1) * d) / depth) * scale

    clock = FakeClock(0.0)
    stores = {slot: SimTier(slot) for slot in env.tier_chain}
    policy = CheckpointPolicy(env, stores, clock=clock)
    rng = random.Random(seed)
    t_fail = (rng.expovariate(1.0 / mtbf)
              if math.isfinite(mtbf) and mtbf > 0 else math.inf)
    useful = 0.0
    write_total = 0.0
    rework_total = 0.0
    restore_total = 0.0
    failures = 0
    writes = 0
    version = 0
    tier_writes: Dict[str, int] = {s: 0 for s in env.tier_chain}
    last_cp_t = 0.0     # sim time the last checkpoint finished landing
    restore_cost = summary.restore_seconds
    if restore_cost is None:
        deepest = env.tier_chain[-1] if env.tier_chain else "pfs"
        restore_cost = tier_cost(deepest, True)

    for it in range(horizon_steps):
        s = steps[it % len(steps)]
        clock.advance(s)
        useful += s
        policy.observe_step_seconds(s)
        d = policy.need_checkpoint(it, next_version=version + 1)
        if d.write:
            version += 1
            writes += 1
            cost = 0.0
            for slot in d.tiers:
                c = tier_cost(slot, d.full)
                cost += c
                stores[slot].record_write(c, summary.payload_bytes)
                policy.note_tier_written(slot)
                tier_writes[slot] = tier_writes.get(slot, 0) + 1
            clock.advance(cost)
            write_total += cost
            policy.record_written(d, version)
            last_cp_t = clock.t
        if clock.t >= t_fail:
            failures += 1
            # everything since the last completed checkpoint is redone —
            # a run with no checkpoint yet loses everything from t=0
            lost = clock.t - (last_cp_t if version > 0 else 0.0)
            rework_total += max(0.0, lost)
            restore_total += restore_cost
            clock.advance(restore_cost)
            policy.reset_estimators()
            policy.notify_restore()
            last_cp_t = clock.t
            t_fail = clock.t + rng.expovariate(1.0 / mtbf)
    # an uncheckpointed tail is exposed work; charge its expected loss so a
    # "never checkpoint" config cannot score 0 overhead on short horizons
    if math.isfinite(mtbf):
        tail = clock.t - (last_cp_t if version > 0 else 0.0)
        exposure = 1.0 - math.exp(-max(0.0, tail) / mtbf)
        rework_total += max(0.0, tail) * exposure * 0.5
    return SimReport(
        overrides={k: str(v) for k, v in overrides.items()},
        horizon_steps=horizon_steps, seed=seed,
        useful_seconds=useful, write_seconds=write_total,
        rework_seconds=rework_total, restore_seconds=restore_total,
        failures=failures, writes=writes, tier_writes=tier_writes,
    )
