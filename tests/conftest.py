"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the single real
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import os

import numpy as np
import pytest

from repro.core.env import CraftEnv
from repro.core.mem_level import MemFabric


@pytest.fixture(autouse=True)
def _mem_fabric_isolation():
    """The memory-tier fabric is process-global; wipe it around every test so
    checkpoint names reused across tests can't serve stale RAM state."""
    MemFabric.instance().reset()
    yield
    MemFabric.instance().reset()


@pytest.fixture()
def env(tmp_path):
    """A CraftEnv writing into the test's tmp dir (sync, node tier on)."""
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_NODE_CP_PATH": str(tmp_path / "node"),
    })


@pytest.fixture()
def env_pfs_only(tmp_path):
    return CraftEnv.capture({
        "CRAFT_CP_PATH": str(tmp_path / "pfs"),
        "CRAFT_USE_SCR": "0",
    })


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
