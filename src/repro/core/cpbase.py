"""CpBase — the extension point of the CRAFT checkpoint library.

The paper's design (Fig. 2): every checkpointable data type derives from a
base class with three pure-virtual functions, ``read()``, ``write()`` and
``update()``.  The ``Checkpoint`` class holds a map of named CpBase objects
and drives those three calls.

JAX adaptation: ``update()`` is where device state becomes host state — for a
``jax.Array`` it snapshots the addressable shards (device→host DMA overlaps
with subsequent compute on TPU).  ``write()``/``read()`` are pure host-side
file IO and can therefore run on the asynchronous writer thread.
"""
from __future__ import annotations

import abc
import dataclasses
import threading
from pathlib import Path
from typing import Callable, Optional, Sequence


@dataclasses.dataclass
class IOContext:
    """Context threaded through every read/write call.

    ``proc_rank`` / ``proc_count`` identify the writing process (paper: rank
    embedded in process-local file names); ``compress``/``checksum`` select the
    codec, and ``checksum_db`` collects per-file digests for the manifest.

    Codec pipeline fields (on-disk format v1): ``codec_version`` picks the
    array file format (0 = legacy monolithic blob, 1 = chunked), and
    ``chunk_bytes`` the chunk granularity.  ``fanout``, when set, is a
    ``fanout(jobs) -> results`` callable backed by the IO worker pool; the
    storage layer routes independent per-array and per-chunk work through it,
    so reads/writes issued from several threads share one ``IOContext`` —
    hence the lock around ``checksum_db`` updates.
    """

    proc_rank: int = 0
    proc_count: int = 1
    compress: str = "none"          # none | zstd
    checksum: str = "crc32"         # crc32 | fletcher | none
    # Per-file digest manifest: filled at write (keyed by path relative to
    # ``rel_root``), persisted into the version metadata at publish; restore
    # checks every manifest file is present before reading (payload integrity
    # itself is verified by the in-file digests).
    checksum_db: Optional[dict] = None
    rel_root: Optional[Path] = None      # staging root the manifest keys on
    codec_version: int = 1          # 0 = legacy blob, 1 = chunked
    chunk_bytes: int = 4 * 1024 * 1024
    # Parallel fanout hook: fanout(list[callable]) -> list of results, in
    # order.  None means "run inline" (no pool available).
    fanout: Optional[Callable[[Sequence[Callable]], list]] = None
    # Restore-time hook: maps a stored global numpy array onto the live
    # sharding/topology (elastic restore).  Installed by jax-aware types.
    device_put: Optional[Callable] = None
    # Memory-tier fast path: maps str(path) of an array file to its already-
    # decoded (read-only) ndarray; ``storage.read_array`` serves hits without
    # touching the filesystem or re-running the codec.  Installed by
    # ``MemStore.read_ctx_overrides`` (payloads are digest-verified at
    # publish, so no re-verification happens on this path).
    array_cache: Optional[dict] = None
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def record_checksum(self, rel_name: str, digest: int) -> None:
        if self.checksum_db is not None:
            with self._lock:
                self.checksum_db[rel_name] = digest


class CpBase(abc.ABC):
    """Base class of every checkpointable data type (paper Fig. 2).

    Subclasses implement:
      * ``update()`` — refresh the internal write-buffer from the live data
        (only used for copy-based asynchronous checkpointing; synchronous
        writes may fold this into ``write()``).
      * ``write(dir_path, ctx)`` — serialize the buffer into ``dir_path``.
      * ``read(dir_path, ctx)`` — restore the live data from ``dir_path``.
    """

    #: When True the object snapshots into a private buffer on ``update()``
    #: so the live data can be mutated while the writer thread runs.
    needs_copy_for_async: bool = True

    @abc.abstractmethod
    def update(self) -> None:
        """Snapshot live data into the write buffer (async copy mode)."""

    @abc.abstractmethod
    def write(self, dir_path: Path, ctx: IOContext) -> None:
        """Serialize the (buffered) data under ``dir_path``."""

    @abc.abstractmethod
    def read(self, dir_path: Path, ctx: IOContext) -> None:
        """Restore live data from ``dir_path`` (raises on missing/corrupt)."""

    def nbytes(self) -> int:
        """Approximate checkpoint payload size (for tier policy / stats)."""
        return 0


class CheckpointError(RuntimeError):
    """Raised on unreadable / corrupt / inconsistent checkpoint data."""
