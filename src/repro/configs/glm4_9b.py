"""glm4-9b — dense decoder, RoPE, extreme GQA (kv=2).

[hf:THUDM/glm-4-9b; hf]  40L d_model=4096 32H (kv=2) d_ff=13696
vocab=151552.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="glm4-9b", family="dense",
    n_layers=40, d_model=4096, vocab=151552,
    attn_type="gqa", n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    n_layers=2, d_model=64, vocab=512, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128,
)
