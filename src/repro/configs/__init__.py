"""Architecture registry + assigned input shapes (40 cells).

``--arch <id>`` resolution, the four assigned shapes, and the cell matrix
with the sanctioned ``long_500k`` skips (pure full-attention archs cannot
decode a 524k dense KV cache sub-quadratically; SSM / hybrid / SWA archs
run it — see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

from repro.configs import (
    deepseek_v3_671b,
    falcon_mamba_7b,
    glm4_9b,
    h2o_danube_1p8b,
    kimi_k2_1t_a32b,
    llava_next_34b,
    musicgen_medium,
    phi4_mini_3p8b,
    yi_34b,
    zamba2_2p7b,
)
from repro.models.common import ModelConfig

_MODULES = {
    "zamba2-2.7b": zamba2_2p7b,
    "musicgen-medium": musicgen_medium,
    "falcon-mamba-7b": falcon_mamba_7b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "phi4-mini-3.8b": phi4_mini_3p8b,
    "yi-34b": yi_34b,
    "h2o-danube-1.8b": h2o_danube_1p8b,
    "glm4-9b": glm4_9b,
    "llava-next-34b": llava_next_34b,
}

ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

#: archs with sub-quadratic attention state — the only ones long_500k runs on
SUBQUADRATIC = ("zamba2-2.7b", "falcon-mamba-7b", "h2o-danube-1.8b")


#: runtime-registered configs (user presets, e.g. the 100M example model)
_EXTRA: Dict[str, tuple] = {}


def register_config(arch_id: str, cfg: ModelConfig,
                    tiny: Optional[ModelConfig] = None) -> None:
    """Register a custom architecture so ``--arch <id>`` resolves to it."""
    _EXTRA[arch_id] = (cfg, tiny if tiny is not None else cfg)


def get_config(arch_id: str, tiny: bool = False) -> ModelConfig:
    if arch_id in _EXTRA:
        return _EXTRA[arch_id][1 if tiny else 0]
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; known: {', '.join(ARCH_IDS)}")
    mod = _MODULES[arch_id]
    return mod.TINY if tiny else mod.CONFIG


def cell_supported(arch_id: str, shape: str) -> Tuple[bool, Optional[str]]:
    """(supported, reason-if-skipped) for one (arch × shape) cell."""
    if shape == "long_500k" and arch_id not in SUBQUADRATIC:
        return False, ("pure full-attention arch: a 524k dense KV decode is "
                       "not sub-quadratic (sanctioned skip, DESIGN.md §4)")
    return True, None


def cells(include_skipped: bool = False) -> Iterator[Tuple[str, str]]:
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, _ = cell_supported(arch, shape)
            if ok or include_skipped:
                yield arch, shape
