"""SimComm — deterministic in-process FTComm backend (threads as ranks).

Purpose (DESIGN.md §2): unit-test the ULFM semantics (revoke / shrink /
agree / spawn ordering, AFT-zone retry) without real processes, and run
recovery *bookkeeping* scaling benchmarks far past what one CPU can host as
real processes.  The fault model is ``world.kill(rank)``: the rank is marked
fail-stop dead (its thread raises an uncatchable ``KilledError`` at its next
communicator call), and every peer discovers the failure at its next
operation — exactly ULFM's detection contract.
"""
from __future__ import annotations

import threading
from collections import defaultdict
from typing import Callable, Dict, List, Optional

from repro.core import trace
from repro.core.comm import FTComm, KilledError
from repro.core.env import CraftEnv
from repro.core.ftengine import CollectiveEngine, NodePool


class SimWorld:
    """Holds the engine, the rank threads, and the fault-injection API."""

    def __init__(
        self,
        n_procs: int,
        procs_per_node: int = 1,
        spare_nodes: int = 0,
        env: Optional[CraftEnv] = None,
    ):
        self.n_procs = n_procs
        self.ppn = max(1, procs_per_node)
        self.env = env if env is not None else CraftEnv.capture({})
        n_nodes = (n_procs + self.ppn - 1) // self.ppn
        members = {r: r // self.ppn for r in range(n_procs)}
        self.engine = CollectiveEngine(members)
        for r in range(n_procs):
            self.engine.set_occupant(0, r, f"u{r}")
        self.engine.set_spawn_policy(self.env.comm_spawn_policy)
        self.pool = NodePool(n_nodes, spare_nodes)
        self._lock = threading.Lock()
        self._dead: set = set()
        self._threads: List[threading.Thread] = []
        self._results: Dict[int, object] = {}
        self._errors: Dict[int, BaseException] = {}
        self._fn: Optional[Callable] = None
        self._uid = 0
        # fault-domain observers: fn(rank) fires inside kill() so RAM-tier
        # state vanishes atomically with the fail-stop (see FTComm.fault_domain)
        self._kill_hooks: List[Callable[[int], None]] = []

    # ---------------------------------------------------------------- launch
    def run(self, fn: Callable[["SimComm"], object], timeout: float = 120.0):
        """Run ``fn(comm)`` on every rank; returns {token: result} of every
        incarnation that returned (dead incarnations are absent)."""
        self._fn = fn
        for r in range(self.n_procs):
            self._start_thread(r, eid=0, replacement=False, uid=f"u{r}")
        import time as _time
        deadline = _time.monotonic() + timeout
        i = 0
        while True:
            with self._lock:
                threads = list(self._threads)
            if i >= len(threads):
                break
            t = threads[i]
            t.join(timeout=max(0.0, deadline - _time.monotonic()))
            if t.is_alive():
                raise TimeoutError(f"sim thread {t.name} did not finish")
            i += 1
        if self._errors:
            rank, err = next(iter(self._errors.items()))
            raise RuntimeError(f"sim rank {rank} crashed: {err!r}") from err
        return dict(self._results)

    def _start_thread(self, rank: int, eid: int, replacement: bool,
                      uid: Optional[str] = None) -> None:
        if uid is None:
            with self._lock:
                self._uid += 1
                uid = f"spawn{self._uid}"

        def runner():
            comm = SimComm(self, rank, eid, replacement=replacement, uid=uid)
            if replacement:
                self.engine.register_member(eid, rank, token=uid)
            try:
                result = self._fn(comm)
                with self._lock:
                    self._results[uid] = result
            except KilledError:
                pass                      # this rank was the fault-injection target
            except BaseException as exc:  # surfaced to run()
                with self._lock:
                    self._errors[rank] = exc

        t = threading.Thread(target=runner, name=f"sim-{uid}-r{rank}", daemon=True)
        with self._lock:
            self._threads.append(t)
        t.start()

    # ----------------------------------------------------------------- faults
    def kill(self, rank: int, eid: Optional[int] = None) -> None:
        """Fail-stop the incarnation holding ``rank`` (pkill -9 analog).

        ``eid`` defaults to the newest epoch containing that rank.
        """
        if eid is None:
            eid = max(
                e for e, ep in self.engine._epochs.items() if rank in ep.members
            )
        token = self.engine.epoch(eid).occupants.get(rank)
        if token is None:
            raise RuntimeError(f"no live incarnation at (epoch {eid}, rank {rank})")
        trace.TRACER.emit("kill", rank=int(rank))
        with self._lock:
            self._dead.add(token)
            hooks = list(self._kill_hooks)
        for hook in hooks:
            hook(rank)
        self.engine.mark_dead(token)

    def add_kill_hook(self, fn: Callable[[int], None]) -> None:
        """Register an observer called with the rank id on every kill()."""
        with self._lock:
            if fn not in self._kill_hooks:
                self._kill_hooks.append(fn)

    def is_dead_token(self, token) -> bool:
        with self._lock:
            return token in self._dead

    # ---------------------------------------------------------------- spawner
    def spawner(self, rank: int, node: int, eid: int) -> None:
        self._start_thread(rank, eid=eid, replacement=True)


class SimComm(FTComm):
    def __init__(self, world: SimWorld, rank: int, eid: int,
                 replacement: bool = False, uid: Optional[str] = None):
        self._world = world
        self._rank = rank
        self._eid = eid
        self._uid = uid
        self._replacement = replacement
        self._seq: Dict[tuple, int] = defaultdict(int)
        self._last_recovery: dict = {}
        ep = world.engine.epoch(eid)
        self._size = ep.size
        self._node = ep.members[rank]

    # --- liveness guard -------------------------------------------------------
    def _check_alive(self) -> None:
        if self._uid is not None and self._world.is_dead_token(self._uid):
            raise KilledError()

    def _next_seq(self, channel: str) -> int:
        key = (self._eid, channel)
        s = self._seq[key]
        self._seq[key] = s + 1
        return s

    # --- identity ---------------------------------------------------------------
    @property
    def rank(self) -> int:
        return self._rank

    @property
    def size(self) -> int:
        return self._size

    @property
    def epoch(self) -> int:
        return self._eid

    def node_id(self) -> int:
        return self._node

    def procs_per_node(self) -> int:
        return self._world.ppn

    # --- collectives ---------------------------------------------------------------
    def barrier(self, channel: str = "main") -> None:
        self._check_alive()
        self._world.engine.collective(
            self._eid, channel, self._next_seq(channel), "barrier", self._rank,
            timeout=self._deadline(),
        )

    def allreduce(self, value, op: str = "sum", channel: str = "main"):
        self._check_alive()
        return self._world.engine.collective(
            self._eid, channel, self._next_seq(channel), op, self._rank,
            value=value, timeout=self._deadline(),
        )

    def bcast(self, value, root: int = 0, channel: str = "main"):
        self._check_alive()
        return self._world.engine.collective(
            self._eid, channel, self._next_seq(channel), "bcast", self._rank,
            value=value, root=root, timeout=self._deadline(),
        )

    def _deadline(self) -> Optional[float]:
        return None

    # --- ULFM ---------------------------------------------------------------
    def revoke(self) -> None:
        self._check_alive()
        self._world.engine.revoke(self._eid)

    def agree(self, flag: bool = True) -> bool:
        self._check_alive()
        return self._world.engine.collective(
            self._eid, "__agree", self._next_seq("__agree"), "and", self._rank,
            value=bool(flag), fault_tolerant=True,
        )

    def recover(self, policy: Optional[str] = None) -> "SimComm":
        self._check_alive()
        policy = (policy or self._world.env.comm_recovery_policy).upper()
        view = self._world.engine.recover(
            self._eid, self._rank, policy, self._world.pool,
            spawner=self._world.spawner,
        )
        self._last_recovery = view["stats"]
        new = SimComm(self._world, view["rank"], view["eid"], uid=self._uid)
        new._last_recovery = view["stats"]
        return new

    def failed_ranks(self) -> List[int]:
        return self._world.engine.failed_ranks(self._eid)

    def empirical_mtbf(self) -> Optional[float]:
        """Observed MTBF from the engine's failure log (None until the first
        kill) — feeds the checkpoint scheduler's Daly intervals."""
        return self._world.engine.empirical_mtbf()

    def last_recovery_stats(self) -> dict:
        return dict(self._last_recovery)

    @property
    def default_recovery_policy(self):
        return self._world.env.comm_recovery_policy

    def is_replacement(self) -> bool:
        return self._replacement

    def fault_domain(self):
        return self._world
