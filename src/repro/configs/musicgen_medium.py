"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (kv=24 = MHA) d_ff=6144
vocab=2048.  The EnCodec frontend + codebook-interleaving is a STUB per
the assignment: ``input_specs()`` supplies precomputed frame embeddings
added to the token embeddings; the backbone is the deliverable.
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, vocab=2048,
    attn_type="gqa", n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144,
    frontend="audio", n_patches=64,   # conditioning-frame prefix (stub)
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    n_layers=3, d_model=64, vocab=128, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128,
)
