"""Model zoo: composable JAX modules covering the ten assigned architectures.

Pure-functional modules: each exposes ``init(key, cfg) -> params`` (nested
dict of arrays), ``logical(cfg) -> same-shape tree of logical-dim tuples``
(consumed by :mod:`repro.sharding`), and ``apply(params, ...)``.
"""
from repro.models.common import ModelConfig  # noqa: F401
