from repro.optim.adamw import (  # noqa: F401
    OptimConfig, adamw_init, adamw_update, opt_state_logical,
    warmup_cosine,
)
