"""Mixture-of-experts FFN with top-k routing and expert parallelism.

TPU-native formulation (GShard grouped dispatch): tokens are reshaped into
``(groups, group_size)`` and each group routes into a dense
``(experts, capacity)`` slot buffer with one-hot dispatch/combine einsums,
so the whole layer is MXU matmuls — no host-side gather/scatter.  The group
axis carries the ``batch`` logical name (sharded over the data axes) and
expert weights carry the ``experts`` logical axis (sharded over the
``model`` mesh axis = EP); XLA inserts the all-to-all dispatch collectives
automatically under GSPMD.

Grouping is what keeps the one-hot dispatch tensor sub-quadratic: flat
(T, E, C) dispatch is O(T²·k) elements at T = 10⁶ train tokens (petabytes);
grouped (G, S, E, C) with S = ``moe_group_size`` tokens per group is
O(T·E·C_g) with C_g = ceil(S·k·cf/E) — megabytes per device at the assigned
shapes.  Per-group capacity semantics (tokens overflowing their group's
expert slots are dropped) is standard GShard/Switch behavior, and the
Switch-style auxiliary loss keeps the router near-uniform so drops stay
rare.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, mlp_apply, mlp_init, mlp_logical
from repro.sharding.activations import constrain


def moe_init(key, cfg):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k_router, k_w, k_shared = jax.random.split(key, 3)
    kg, ku, kd = jax.random.split(k_w, 3)
    params = {
        "router": dense_init(k_router, (d, e), d, jnp.float32),
        "w_gate": dense_init(kg, (e, d, f), d, cfg.dtype),
        "w_up": dense_init(ku, (e, d, f), d, cfg.dtype),
        "w_down": dense_init(kd, (e, f, d), f, cfg.dtype),
    }
    if cfg.n_shared_experts:
        params["shared"] = mlp_init(
            k_shared, cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts
        )
    return params


def moe_logical(cfg):
    out = {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared_experts:
        out["shared"] = mlp_logical(cfg)
    return out


def _capacity(group_size: int, cfg) -> int:
    raw = group_size * cfg.top_k / cfg.n_experts * cfg.capacity_factor
    return max(cfg.top_k, int(math.ceil(raw / 8.0)) * 8)   # pad to 8 (VREG)


def _group(t: int, cfg) -> int:
    """Tokens per dispatch group: ``moe_group_size`` capped at T."""
    s = min(cfg.moe_group_size, t)
    while t % s:               # t is B·L (powers of two at assigned shapes)
        s -= 1
    return s


def moe_apply(params, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,L,D), aux_loss scalar)."""
    b, l, d = x.shape
    t = b * l
    e, k = cfg.n_experts, cfg.top_k
    s = _group(t, cfg)
    g = t // s
    c = _capacity(s, cfg)
    xg = x.reshape(g, s, d)                                # groups follow batch

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["router"])                  # (G, S, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # (G, S, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)  # renormalize top-k

    # Switch-style load-balance auxiliary loss (global mean over groups).
    me = jnp.mean(probs, axis=(0, 1))                                # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    )
    aux = e * jnp.sum(me * ce)

    # Position of each (token, k) within its group-local expert buffer.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)      # (G,S,k,E)
    flat = onehot.reshape(g, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                        # (G,S*k,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(g, s, k)          # (G,S,k)
    keep = (pos < c).astype(jnp.float32)
    gate_vals = gate_vals * keep

    # dispatch (G, S, E, C) — one-hot over both expert id and capacity slot
    pos_oh = jax.nn.one_hot(pos, c, dtype=jnp.float32) * keep[..., None]
    disp = jnp.einsum("gske,gskc->gsec", onehot, pos_oh)
    comb = jnp.einsum("gske,gskc,gsk->gsec", onehot, pos_oh, gate_vals)
    disp = constrain(disp, "batch", None, "experts", None)
    comb = constrain(comb, "batch", None, "experts", None)

    # keep the group axis through the expert compute so GSPMD shards it
    # over data while experts shard over model (2-D EP placement)
    expert_in = jnp.einsum("gsec,gsd->egcd", disp.astype(cfg.dtype), xg)
    expert_in = constrain(expert_in, "experts", "batch", None, "embed_act")
    gate = jnp.einsum("egcd,edf->egcf", expert_in, params["w_gate"])
    up = jnp.einsum("egcd,edf->egcf", expert_in, params["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(cfg.dtype) * up
    h = constrain(h, "experts", "batch", None, None)
    expert_out = jnp.einsum("egcf,efd->egcd", h, params["w_down"])
    expert_out = constrain(expert_out, "experts", "batch", None, "embed_act")
    out = jnp.einsum("egcd,gsec->gsd", expert_out, comb.astype(cfg.dtype))

    if cfg.n_shared_experts:
        out = out + mlp_apply(params["shared"], xg)
    return out.reshape(b, l, d), aux
