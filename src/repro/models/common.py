"""ModelConfig — one dataclass covering every assigned architecture family."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    vocab: int
    # --- attention -------------------------------------------------------
    attn_type: str = "gqa"          # gqa | mla | none
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0               # 0 => d_model // n_heads
    window: Optional[int] = None    # sliding-window attention (SWA)
    rope_theta: float = 1e4
    # --- ffn ----------------------------------------------------------------
    d_ff: int = 0
    # --- MLA (deepseek-style multi-head latent attention) --------------------
    q_lora_rank: int = 0            # 0 => dense wq
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mla_absorb: bool = True         # absorbed-matmul decode (§Perf 4.1)
    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    dense_d_ff: int = 0             # d_ff of the leading dense layers
    capacity_factor: float = 1.25
    moe_group_size: int = 256       # tokens per GShard dispatch group
    mtp: bool = False               # multi-token-prediction head (deepseek)
    # --- SSM ------------------------------------------------------------------
    ssm_type: Optional[str] = None  # mamba1 | mamba2
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64          # mamba2 heads = d_inner // ssm_head_dim
    ssm_groups: int = 1             # mamba2 B/C groups
    dt_rank: int = 0                # mamba1; 0 => ceil(d_model / 16)
    ssm_chunk: int = 256            # chunked selective-scan chunk length
    # --- hybrid (zamba2: shared attention block between mamba blocks) --------
    shared_attn_every: int = 0
    # --- modality stub (audio / vlm backbones) --------------------------------
    frontend: Optional[str] = None  # audio | vision
    n_patches: int = 0              # vision tokens prepended (anyres stub)
    # --- numerics / implementation --------------------------------------------
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    scan_layers: bool = True
    remat: bool = True
    logits_fp32: bool = True

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or math.ceil(self.d_model / 16)

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def padded_for_tp(self, tp: int) -> "ModelConfig":
        """Round head counts up so they shard evenly over a ``tp``-way axis.

        The production mesh has a fixed 16-way ``model`` axis; archs like
        yi-34b (56 heads) or phi4-mini (24 heads, 8 KV heads) cannot split
        that evenly.  Replicating attention weights instead would leave the
        whole model axis idle during attention, so we *pad*: n_kv_heads →
        next multiple of tp, n_heads → next common multiple of (tp, kv').
        Padded heads are dead compute whose waste is surfaced by the
        roofline MODEL_FLOPS/HLO_FLOPS ratio (the unpadded config is the
        MODEL_FLOPS basis).  No-op when everything already divides.
        """
        if self.attn_type == "none" or self.n_heads == 0 or tp <= 1:
            return self

        def _up(x: int, mult: int) -> int:
            return ((x + mult - 1) // mult) * mult

        hd = self.hd              # freeze head_dim before head counts move
        h = _up(self.n_heads, tp)
        if self.attn_type == "mla":
            if h == self.n_heads:
                return self
            return self.replace(n_heads=h, head_dim=hd)
        kv = self.n_kv_heads
        kv2 = kv if kv % tp == 0 else _up(kv, tp)
        h2 = _up(h, kv2)          # group size must stay integral
        if h2 == self.n_heads and kv2 == self.n_kv_heads:
            return self
        return self.replace(n_heads=h2, n_kv_heads=kv2, head_dim=hd)

    # ------------------------------------------------------- parameter count
    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        n = v * d                              # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer_attn = 0
        if self.attn_type == "gqa":
            hd = self.hd
            per_layer_attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d
        elif self.attn_type == "mla":
            qk = self.qk_nope_dim + self.qk_rope_dim
            if self.q_lora_rank:
                per_layer_attn += d * self.q_lora_rank \
                    + self.q_lora_rank * self.n_heads * qk
            else:
                per_layer_attn += d * self.n_heads * qk
            per_layer_attn += d * (self.kv_lora_rank + self.qk_rope_dim)
            per_layer_attn += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.v_head_dim
            )
            per_layer_attn += self.n_heads * self.v_head_dim * d
        dense_ffn = 3 * d * self.d_ff
        moe_ffn = 0
        if self.n_experts:
            moe_ffn = self.n_experts * 3 * d * self.moe_d_ff \
                + self.n_shared_experts * 3 * d * self.moe_d_ff \
                + d * self.n_experts          # router
        ssm = 0
        if self.ssm_type == "mamba1":
            di, st, dtr = self.d_inner, self.ssm_state, self.dt_rank_eff
            ssm = d * 2 * di + self.ssm_conv * di + di * (dtr + 2 * st) \
                + dtr * di + di * st + di + di * d
        elif self.ssm_type == "mamba2":
            di, st = self.d_inner, self.ssm_state
            nh, g = self.ssm_heads, self.ssm_groups
            proj_in = d * (2 * di + 2 * g * st + nh)
            ssm = proj_in + self.ssm_conv * (di + 2 * g * st) + nh \
                + di + di * d + nh            # A_log, D, dt_bias, norm
        total = n
        if self.family == "hybrid":
            # shared attention+ffn block counted once (weights are shared)
            n_shared_applications = (
                self.n_layers // self.shared_attn_every
                if self.shared_attn_every else 0
            )
            total += self.n_layers * (ssm + 2 * d)
            if n_shared_applications:
                hd = self.hd
                shared = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                          + self.n_heads * hd * d) + 3 * d * self.d_ff + 2 * d
                total += shared
        elif self.ssm_type:
            total += self.n_layers * (ssm + d)
        elif self.n_experts:
            n_moe = self.n_layers - self.first_dense_layers
            total += self.first_dense_layers * (
                per_layer_attn + 3 * d * (self.dense_d_ff or self.d_ff) + 2 * d
            )
            total += n_moe * (per_layer_attn + moe_ffn + 2 * d)
        else:
            total += self.n_layers * (per_layer_attn + dense_ffn + 2 * d)
        total += d                             # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top-k + shared, not all)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        all_experts = self.n_experts * 3 * d * self.moe_d_ff
        active_experts = self.top_k * 3 * d * self.moe_d_ff
        n_moe = self.n_layers - self.first_dense_layers
        return self.param_count() - n_moe * (all_experts - active_experts)
