"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8, per the
assignment) d_ff(expert)=2048 vocab=163840; MoE: 1 shared + 384 routed
experts, top-8; first layer dense (d_ff 18432).  head_dim=128 chosen
explicitly (MXU-aligned; the assignment gives no head_dim).
"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, vocab=163840,
    attn_type="gqa", n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=18432, dense_d_ff=18432, first_dense_layers=1,
    n_experts=384, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    tie_embeddings=False,
)

TINY = CONFIG.replace(
    n_layers=3, d_model=64, vocab=512, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, dense_d_ff=128, first_dense_layers=1,
    n_experts=8, top_k=2, moe_d_ff=64,
)
