"""Deterministic synthetic token pipeline with a checkpointable cursor.

Counter-based (Philox) generation makes the stream a pure function of
``(seed, step, shard)``: restart from a checkpointed cursor reproduces the
exact batch sequence — no filesystem state, no iterator pickling — and each
data-parallel process generates only its own shard (host data loading).

The "tokens" follow a Zipfian-ish distribution (realistic embedding-gather
skew) with ``labels = tokens shifted left`` (next-token prediction).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np


class DataCursor:
    """Checkpointable position in the stream (add to a Checkpoint as POD)."""

    __slots__ = ("step",)

    def __init__(self, step: int = 0):
        self.step = step


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0
    zipf_a: float = 1.2

    def __post_init__(self):
        if self.global_batch % self.n_shards:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"{self.n_shards} shards")
        self.local_batch = self.global_batch // self.n_shards

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """The (step, shard) batch: {"tokens", "labels"} of (local_B, L)."""
        rng = np.random.Generator(np.random.Philox(
            key=[(self.seed << 32) | (step & 0xFFFFFFFF),
                 (self.shard << 32) | 0xC0FFEE]))
        raw = rng.zipf(self.zipf_a, size=(self.local_batch, self.seq_len + 1))
        tokens = (raw - 1) % self.vocab
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def batches(self, cursor: DataCursor, n: Optional[int] = None):
        """Iterate from the cursor, advancing it (resume-exact)."""
        produced = 0
        while n is None or produced < n:
            yield self.batch(cursor.step)
            cursor.step += 1
            produced += 1
