"""``craft tune`` — coordinate-descent policy auto-tuning over a recorded
trace (the *tune* third of the record → replay → tune loop).

Given a trace recorded with ``CRAFT_TRACE`` (``core/trace.py``), distill it
into empirical distributions (``core/simulate.summarize``) and search the
scheduling knobs for the config with the lowest *expected overhead* —
simulated write + rework-after-failure + restore seconds
(``core/simulate.simulate_config``).

Search space (each dimension only when the recorded config makes it live):

* per-slot ``CRAFT_TIER_EVERY`` opportunity counts, every chained slot;
* ``CRAFT_RS_PARITY`` when the node tier runs Reed-Solomon redundancy;
* ``CRAFT_MEM_REPLICAS`` when the RAM tier is chained;
* ``CRAFT_DELTA_MAX_CHAIN`` when the delta codec is on.

The descent starts **from the as-run config** and only ever moves to a
strictly better score, so the recommendation can never regress the
simulated as-run overhead — that invariant is what the CI ``tune-smoke``
job (``--fail-on-regression``) re-checks end to end.

Everything here is deterministic: same trace + same seed ⇒ same
recommendation (``tests/test_property.py`` pins it).

CLI: ``python -m repro.tune --trace run.jsonl [--json BENCH_tune.json]``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.env import CraftEnv
from repro.core.simulate import (
    SimReport, TraceSummary, simulate_config, summarize,
)

__all__ = ["tune", "recommend_env_block", "tune_trace"]

#: Candidate per-slot opportunity counts (powers of two: the overhead curve
#: is flat near Daly's optimum, so a ×2 grid brackets it within ~¼ of the
#: achievable improvement at a fraction of the evaluations).
COUNT_GRID = (1, 2, 4, 8, 16, 32, 64, 128, 256)
RS_PARITY_GRID = (1, 2, 3)
MEM_REPLICAS_GRID = (1, 2, 3)
DELTA_CHAIN_GRID = (1, 2, 4, 8, 16)
MAX_SWEEPS = 4


def _tier_every_string(counts: Dict[str, int]) -> str:
    return ",".join(f"{slot}:{n}" for slot, n in counts.items())


def _as_run_counts(env: CraftEnv, summary: TraceSummary) -> Dict[str, int]:
    """The recorded config's effective per-slot counts — the descent's
    starting point.  ``auto`` (Daly) slots start from the count nearest
    their recorded interval; legacy slots from their modulo equivalents."""
    step = max(1e-9, summary.mean_step())
    counts: Dict[str, int] = {}
    for slot in env.tier_chain:
        spec = env.tier_every_for(slot)
        if isinstance(spec, int):
            counts[slot] = max(1, spec)
        elif spec == "auto":
            # seed from the recorded write rate: observed writes per slot
            # over the trace span, converted to an opportunity count
            cost = summary.tier_full_cost.get(slot) \
                or summary.tier_delta_cost.get(slot)
            if cost:
                from repro.core.scheduler import daly_interval
                interval = daly_interval(cost, summary.mtbf())
                counts[slot] = max(1, min(COUNT_GRID[-1],
                                          int(round(interval / step))))
            else:
                counts[slot] = 1
        else:   # legacy: every version, except PFS behind a node tier
            if slot == "pfs" and "node" in env.tier_chain \
                    and env.pfs_every > 1:
                counts[slot] = env.pfs_every
            else:
                counts[slot] = 1
    return counts


def _dimensions(env: CraftEnv, counts: Dict[str, int]) -> List[Tuple]:
    """[(key, slot_or_None, candidate values)] — the coordinate axes."""
    dims: List[Tuple] = []
    for slot in env.tier_chain:
        grid = sorted(set(COUNT_GRID) | {counts[slot]})
        dims.append(("CRAFT_TIER_EVERY", slot, tuple(grid)))
    if "node" in env.tier_chain and env.node_redundancy.upper() == "RS":
        grid = sorted(set(RS_PARITY_GRID) | {env.rs_parity})
        dims.append(("CRAFT_RS_PARITY", None, tuple(grid)))
    if "mem" in env.tier_chain:
        grid = sorted(set(MEM_REPLICAS_GRID) | {env.mem_replicas})
        dims.append(("CRAFT_MEM_REPLICAS", None, tuple(grid)))
    if env.delta:
        grid = sorted(set(DELTA_CHAIN_GRID) | {env.delta_max_chain})
        dims.append(("CRAFT_DELTA_MAX_CHAIN", None, tuple(grid)))
    return dims


def _overrides(counts: Dict[str, int], scalars: Dict[str, int]) -> dict:
    out = {"CRAFT_TIER_EVERY": _tier_every_string(counts)}
    out.update({k: str(v) for k, v in scalars.items()})
    return out


def tune(summary: TraceSummary, *, seed: int = 0,
         horizon_steps: Optional[int] = None,
         max_sweeps: int = MAX_SWEEPS) -> dict:
    """Coordinate descent from the as-run config; returns the scorecard.

    ``{"as_run": {...}, "recommended": {...}, "improvement_pct": float,
    "evaluations": int, "sweeps": int}`` where each side carries its
    simulated :class:`SimReport` dict and its ``CRAFT_*`` override map.
    """
    env = CraftEnv.capture({"CRAFT_CP_PATH": "/unused",
                            **summary.config_env})
    counts = _as_run_counts(env, summary)
    scalars = {}
    dims = _dimensions(env, counts)
    for key, _slot, _grid in dims:
        if key == "CRAFT_RS_PARITY":
            scalars[key] = env.rs_parity
        elif key == "CRAFT_MEM_REPLICAS":
            scalars[key] = env.mem_replicas
        elif key == "CRAFT_DELTA_MAX_CHAIN":
            scalars[key] = env.delta_max_chain

    evaluations = 0
    cache: Dict[Tuple, SimReport] = {}

    def score(counts_: Dict[str, int], scalars_: Dict[str, int]) -> SimReport:
        nonlocal evaluations
        key = (tuple(sorted(counts_.items())),
               tuple(sorted(scalars_.items())))
        hit = cache.get(key)
        if hit is not None:
            return hit
        evaluations += 1
        rep = simulate_config(summary, _overrides(counts_, scalars_),
                              seed=seed, horizon_steps=horizon_steps)
        cache[key] = rep
        return rep

    # the as-run score: the recorded config simulated under the same model
    # and seed — the yardstick the recommendation must never regress
    as_run = simulate_config(summary, {}, seed=seed,
                             horizon_steps=horizon_steps)
    best = score(counts, scalars)
    if as_run.overhead_seconds < best.overhead_seconds:
        # the count-normalized start scored worse than the literal as-run
        # config (auto-slot seeding is approximate): keep the literal one
        # as the floor; the descent must beat it to recommend anything
        best = as_run
    sweeps = 0
    for sweep in range(max_sweeps):
        improved = False
        for key, slot, grid in dims:
            for value in grid:
                if key == "CRAFT_TIER_EVERY":
                    if counts[slot] == value:
                        continue
                    trial_counts = {**counts, slot: value}
                    trial_scalars = dict(scalars)
                else:
                    if scalars.get(key) == value:
                        continue
                    trial_counts = dict(counts)
                    trial_scalars = {**scalars, key: value}
                rep = score(trial_counts, trial_scalars)
                if rep.overhead_seconds < best.overhead_seconds:
                    best = rep
                    counts, scalars = trial_counts, trial_scalars
                    improved = True
        sweeps = sweep + 1
        if not improved:
            break

    recommended = best
    rec_overrides = dict(recommended.overrides)
    improvement = 0.0
    if as_run.overhead_seconds > 0:
        improvement = 100.0 * (as_run.overhead_seconds
                               - recommended.overhead_seconds) \
            / as_run.overhead_seconds
    return {
        "as_run": {"overrides": {}, **as_run.as_dict()},
        "recommended": {**recommended.as_dict(),
                        "overrides": rec_overrides},
        "improvement_pct": round(improvement, 3),
        "evaluations": evaluations,
        "sweeps": sweeps,
        "seed": seed,
        "mtbf_seconds": round(summary.mtbf(), 3),
        "mean_step_seconds": round(summary.mean_step(), 6),
    }


def recommend_env_block(result: dict) -> str:
    """The recommendation as a paste-ready shell env block."""
    lines = ["# craft tune recommendation "
             f"(simulated overhead {result['recommended']['overhead_seconds']}s"
             f" vs as-run {result['as_run']['overhead_seconds']}s, "
             f"{result['improvement_pct']}% better)"]
    overrides = result["recommended"]["overrides"]
    if not overrides:
        lines.append("# as-run config already optimal under the model — "
                     "no changes recommended")
    for key in sorted(overrides):
        lines.append(f"export {key}={overrides[key]}")
    return "\n".join(lines)


def tune_trace(path, *, seed: int = 0,
               horizon_steps: Optional[int] = None) -> dict:
    """Convenience: trace file → scorecard (what the CLI calls)."""
    from repro.core.simulate import load_trace

    return tune(summarize(load_trace(path)), seed=seed,
                horizon_steps=horizon_steps)
