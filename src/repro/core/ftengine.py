"""Collective-matching + recovery engine shared by the FTComm backends.

Both the in-process simulator (:mod:`repro.core.comm_sim`) and the real
multiprocessing coordinator (:mod:`repro.runtime.coordinator`) need the same
bookkeeping:

  * **epochs** — one generation of the communicator (ULFM: a communicator
    object); failure breaks an epoch, recovery registers the next one;
  * **collective matching** — ops are keyed by (epoch, channel, seq, op);
    every live member must arrive with the same key (SPMD ordering per
    channel), then all are released with the reduced result;
  * **failure semantics** — a dead member breaks the epoch: normal
    collectives raise ``ProcFailedError``; ``revoke`` poisons the epoch so
    *every* member learns (``RevokedError``); ``agree`` keeps working among
    survivors (ULFM's fault-tolerant agreement), which is what recovery is
    built on;
  * **recovery** — the ULFM recipe (paper §3.2) with per-phase timings
    (paper Table 3): ① revoke+shrink consensus, ② spawn-info generation,
    ③ spawn+merge, ④ rank redistribution, ⑤ resource (spare-node)
    management.  Spawning itself is backend-specific and injected as a
    callback.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import trace
from repro.core.comm import ProcFailedError, RevokedError

_REDUCERS = {
    "sum": lambda vals: sum(vals),
    "min": lambda vals: min(vals),
    "max": lambda vals: max(vals),
    "and": lambda vals: all(vals),
    "or": lambda vals: any(vals),
    "list": lambda vals: list(vals),
}


@dataclass
class EpochState:
    eid: int
    members: Dict[int, int]                  # rank -> node id
    live: Optional[set] = None
    revoked: bool = False
    replacements: set = field(default_factory=set)   # ranks that are respawns
    occupants: Dict[int, object] = field(default_factory=dict)  # rank -> token
    pending_join: set = field(default_factory=set)   # respawns not yet joined

    def __post_init__(self):
        if self.live is None:
            self.live = set(self.members)

    @property
    def broken(self) -> bool:
        # a rank that never joined yet (replacement still booting) is not a
        # failure; a rank that joined and left (died) is.
        return bool(set(self.members) - self.live - self.pending_join)

    @property
    def size(self) -> int:
        return len(self.members)


class NodePool:
    """Bookkeeping of active / failed / spare nodes (paper Table 3 phase ⑤)."""

    def __init__(self, n_nodes: int, spare_nodes: int = 0):
        self.active = list(range(n_nodes))
        self.spares = list(range(n_nodes, n_nodes + spare_nodes))
        self.failed: List[int] = []

    def allocate_replacements(
        self, failed_nodes: List[int], policy: str
    ) -> Dict[int, int]:
        """old node -> node for the replacement procs (REUSE / NO-REUSE).

        NO-REUSE draws from the spare pool ("once a node has a hard failure
        it is likely to fail again"); an exhausted pool falls back to REUSE.
        """
        mapping: Dict[int, int] = {}
        for node in dict.fromkeys(failed_nodes):  # stable-unique
            if policy == "NO-REUSE" and self.spares:
                new = self.spares.pop(0)
                self.failed.append(node)
                if node in self.active:
                    self.active.remove(node)
                self.active.append(new)
            else:  # REUSE (or spare pool exhausted)
                new = node
            mapping[node] = new
        return mapping


class CollectiveEngine:
    def __init__(self, members: Dict[int, int]):
        self._cv = threading.Condition()
        self._epochs: Dict[int, EpochState] = {0: EpochState(0, dict(members))}
        self._next_eid = 1
        self._spawn_policy = "REUSE"
        # key -> {"arrived": {rank: value}, "done": bool, "result": ...}
        self._pending: Dict[Tuple, dict] = {}
        # failure log feeding the checkpoint scheduler's empirical MTBF
        self._t_birth = time.monotonic()
        self._failure_times: List[float] = []

    def _log_failure(self) -> None:
        """Record one observed fail-stop (caller holds ``self._cv``).

        Callers must only log on an actual live→dead transition — a stale
        report of an already-dead rank double-counted would inflate the
        failure rate and shrink every Daly interval derived from it.
        """
        trace.TRACER.emit("failure", count=len(self._failure_times) + 1)
        self._failure_times.append(time.monotonic())

    def empirical_mtbf(self) -> Optional[float]:
        """Observed mean time between failures over this engine's lifetime
        (``None`` until the first failure) — the Daly-formula input when
        ``CRAFT_MTBF_SECONDS`` is unset."""
        with self._cv:
            n = len(self._failure_times)
            if n == 0:
                return None
            return max(time.monotonic() - self._t_birth, 1e-9) / n

    def failure_count(self) -> int:
        with self._cv:
            return len(self._failure_times)

    def set_spawn_policy(self, policy: str) -> None:
        self._spawn_policy = policy

    # ------------------------------------------------------------ membership
    def epoch(self, eid: int) -> EpochState:
        return self._epochs[eid]

    def current_members(self, eid: int) -> Dict[int, int]:
        return dict(self._epochs[eid].members)

    def set_occupant(self, eid: int, rank: int, token) -> None:
        """Record which process incarnation currently holds (eid, rank).

        Ranks are re-numbered by shrinking recovery and re-used by
        non-shrinking respawns, so failure must be tracked per *incarnation*
        (token), never per bare rank id.
        """
        with self._cv:
            self._epochs[eid].occupants[rank] = token

    def mark_dead(self, token) -> None:
        """Fail-stop of one incarnation: breaks every (epoch, rank) slot it
        occupies."""
        with self._cv:
            transitioned = False
            for ep in self._epochs.values():
                for rank, occ in ep.occupants.items():
                    if occ == token and rank in ep.live:
                        ep.live.discard(rank)
                        transitioned = True
            if transitioned:     # one incarnation death = one failure event
                self._log_failure()
            self._cv.notify_all()

    def mark_rank_dead(self, eid: int, rank: int) -> None:
        """Launcher-level death report for an incarnation that never joined
        (died before its first hello — no connection exists to EOF).  Only
        epochs ≤ ``eid`` are touched so a replacement that re-uses the rank
        id in a newer epoch is never hit by a stale report."""
        with self._cv:
            transitioned = False
            for e, ep in self._epochs.items():
                if e <= eid and rank in ep.members:
                    if rank in ep.live or rank in ep.pending_join:
                        transitioned = True
                    ep.live.discard(rank)
                    ep.pending_join.discard(rank)
            if transitioned:     # ignore stale reports of already-dead ranks
                self._log_failure()
            self._cv.notify_all()

    def revoke(self, eid: int) -> None:
        with self._cv:
            self._epochs[eid].revoked = True
            self._cv.notify_all()

    def is_revoked(self, eid: int) -> bool:
        with self._cv:
            return self._epochs[eid].revoked

    def failed_ranks(self, eid: int) -> List[int]:
        with self._cv:
            ep = self._epochs[eid]
            return sorted(set(ep.members) - ep.live - ep.pending_join)

    # ------------------------------------------------------------ collectives
    def collective(
        self,
        eid: int,
        channel: str,
        seq: int,
        op: str,
        rank: int,
        value=None,
        root: int = 0,
        fault_tolerant: bool = False,
        timeout: Optional[float] = None,
    ):
        """Blocking entry of one member into a matched collective.

        ``fault_tolerant=True`` (agree / recovery internals) completes over
        the live set even on a broken or revoked epoch; otherwise failure or
        revocation raises.  ``timeout`` implements the straggler deadline:
        members missing past the deadline are declared failed.
        """
        key = (eid, channel, seq, op, root if op == "bcast" else None)
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            ep = self._epochs[eid]
            st = self._pending.setdefault(key, {"arrived": {}, "done": False})
            st["arrived"][rank] = value
            self._cv.notify_all()
            while True:
                if st["done"]:
                    return st["result"]
                if not fault_tolerant:
                    if ep.revoked:
                        raise RevokedError(f"epoch {eid} revoked")
                    if ep.broken:
                        raise ProcFailedError(failed=self.failed_ranks(eid))
                needed = set(ep.live) if fault_tolerant else set(ep.members)
                if needed and needed <= set(st["arrived"]):
                    st["result"] = self._reduce(op, st, needed, root)
                    st["done"] = True
                    self._cv.notify_all()
                    return st["result"]
                if deadline is not None and time.monotonic() > deadline:
                    missing = sorted(needed - set(st["arrived"]))
                    for r in missing:
                        was_live = r in ep.live or r in ep.pending_join
                        token = ep.occupants.get(r)
                        if token is not None:
                            for e in self._epochs.values():
                                for rk, occ in e.occupants.items():
                                    if occ == token:
                                        e.live.discard(rk)
                                        e.pending_join.discard(rk)
                        ep.live.discard(r)
                        ep.pending_join.discard(r)
                        if was_live:
                            self._log_failure()
                    self._cv.notify_all()
                    raise ProcFailedError(
                        f"collective deadline exceeded, stragglers={missing}",
                        failed=missing,
                    )
                self._cv.wait(timeout=0.05)

    def _reduce(self, op: str, st: dict, needed: set, root: int):
        vals = [st["arrived"][r] for r in sorted(needed & set(st["arrived"]))]
        if op == "barrier":
            return None
        if op == "bcast":
            return st["arrived"].get(root, vals[0] if vals else None)
        if op in _REDUCERS:
            return _REDUCERS[op](vals)
        raise ValueError(f"unknown collective op {op!r}")

    # ---------------------------------------------------------- registration
    def register_epoch(self, eid: int, members: Dict[int, int],
                       live: set, replacements: set,
                       occupants: Optional[Dict[int, object]] = None) -> None:
        with self._cv:
            self._epochs[eid] = EpochState(
                eid, members, live=set(live), replacements=set(replacements),
                occupants=dict(occupants or {}),
                pending_join=set(replacements) - set(live),
            )
            self._cv.notify_all()

    def register_member(self, eid: int, rank: int, token=None) -> None:
        """A spawned replacement announces itself alive in ``eid``."""
        with self._cv:
            ep = self._epochs[eid]
            ep.live.add(rank)
            ep.pending_join.discard(rank)
            if token is not None:
                ep.occupants[rank] = token
            self._cv.notify_all()

    def wait_members_live(self, eid: int, ranks: List[int], timeout: float = 120.0) -> None:
        deadline = time.monotonic() + timeout
        with self._cv:
            while True:
                ep = self._epochs.get(eid)
                if ep is not None and set(ranks) <= ep.live:
                    return
                if time.monotonic() > deadline:
                    raise ProcFailedError(
                        f"replacements {ranks} failed to register in epoch {eid}"
                    )
                self._cv.wait(timeout=0.05)

    # ------------------------------------------------------------ recovery
    def recover(
        self,
        eid: int,
        rank: int,
        policy: str,
        node_pool: NodePool,
        spawner: Optional[Callable[[int, int, int], None]] = None,
    ) -> dict:
        """ULFM recovery recipe; returns the member's view of the new epoch.

        The lowest-ranked survivor executes the heavy steps (spawn-info,
        spawning, epoch registration); everyone else blocks until the plan
        is published.  ``spawner(new_rank, node, new_eid)`` must start a
        replacement that eventually calls ``register_member(new_eid, rank)``.
        """
        t0 = time.perf_counter()
        # ① revoke + shrink consensus over survivors -------------------------
        self.revoke(eid)
        survivors = self.collective(
            eid, "__recover", eid, "list", rank, value=rank, fault_tolerant=True
        )
        t1 = time.perf_counter()
        leader = rank == min(survivors)
        plan_key = (eid, "__plan", eid)
        with self._cv:
            plan_st = self._pending.setdefault(plan_key, {"done": False})
        if leader:
            ep = self.epoch(eid)
            failed = sorted(set(ep.members) - set(survivors))
            new_eid = self._next_eid
            self._next_eid += 1
            if policy == "NON-SHRINKING":
                # ② generate spawn info (nodes per spawn policy) -------------
                members = dict(ep.members)
                failed_nodes = [ep.members[r] for r in failed]
                node_map = node_pool.allocate_replacements(
                    failed_nodes, policy=self._spawn_policy
                )
                for r in failed:
                    members[r] = node_map[ep.members[r]]
                occupants = {
                    r: ep.occupants.get(r) for r in survivors
                    if ep.occupants.get(r) is not None
                }
                self.register_epoch(
                    new_eid, members, live=set(survivors),
                    replacements=set(failed), occupants=occupants,
                )
                t2 = time.perf_counter()
                # ③ spawn + merge --------------------------------------------
                if spawner is not None:
                    for r in failed:
                        spawner(r, members[r], new_eid)
                    self.wait_members_live(new_eid, failed)
                t3 = time.perf_counter()
                rank_map = {r: r for r in survivors}
            else:  # SHRINKING
                t2 = time.perf_counter()
                t3 = t2
                ordered = sorted(survivors)
                members = {i: ep.members[r] for i, r in enumerate(ordered)}
                rank_map = {r: i for i, r in enumerate(ordered)}
                occupants = {
                    i: ep.occupants.get(r) for i, r in enumerate(ordered)
                    if ep.occupants.get(r) is not None
                }
                self.register_epoch(
                    new_eid, members, live=set(members), replacements=set(),
                    occupants=occupants,
                )
            # ④ rank redistribution = publishing the rank map ----------------
            t4 = time.perf_counter()
            # ⑤ resource management happened inside allocate_replacements ----
            t5 = time.perf_counter()
            stats = {
                "policy": policy,
                "spawn_policy": self._spawn_policy,
                "failed": failed,
                "n_survivors": len(survivors),
                "revoke_shrink_s": t1 - t0,
                "spawn_info_s": t2 - t1,
                "spawn_merge_s": t3 - t2,
                "redistribute_s": t4 - t3,
                "resource_mgmt_s": t5 - t4,
                "total_s": t5 - t0,
            }
            with self._cv:
                plan_st["result"] = {"new_eid": new_eid, "rank_map": rank_map,
                                     "stats": stats}
                plan_st["done"] = True
                self._cv.notify_all()
        with self._cv:
            while not plan_st["done"]:
                self._cv.wait(timeout=0.05)
            plan = plan_st["result"]
        new_eid = plan["new_eid"]
        new_rank = plan["rank_map"][rank]
        new_ep = self.epoch(new_eid)
        return {
            "eid": new_eid,
            "rank": new_rank,
            "size": new_ep.size,
            "node": new_ep.members[new_rank],
            "stats": plan["stats"],
        }
