"""Per-arch smoke tests (reduced configs) + decode-vs-forward consistency.

Every assigned architecture instantiates its TINY config, runs one forward
and one train step on CPU, asserts output shapes and finiteness, and checks
that the serving path (prefill + stepwise decode) agrees with the one-shot
forward pass — the strongest cheap correctness check for cache handling.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.optim.adamw import OptimConfig, adamw_init
from repro.train.steps import (
    TrainStepConfig, chunked_cross_entropy, cross_entropy, make_decode_step,
    make_prefill, make_train_step,
)


@pytest.fixture(scope="module")
def keys():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, keys):
    cfg = get_config(arch, tiny=True)
    params = M.init_params(keys, cfg)
    B, L = 2, 32
    toks = jax.random.randint(keys, (B, L), 0, cfg.vocab)
    logits, cache, aux = M.forward(params, cfg, tokens=toks)
    assert logits.shape == (B, L, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert cache is None
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch, keys):
    cfg = get_config(arch, tiny=True)
    params = M.init_params(keys, cfg)
    ocfg = OptimConfig(lr=1e-2, master_fp32=False, warmup_steps=1,
                       total_steps=10, clip_norm=1e9)
    step = jax.jit(make_train_step(cfg, ocfg, TrainStepConfig(loss_chunk=16)))
    opt = adamw_init(params, ocfg)
    toks = jax.random.randint(keys, (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for _ in range(4):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]      # same batch → loss must drop


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch, keys):
    """argmax of stepwise decode logits == argmax of the one-shot forward."""
    cfg = get_config(arch, tiny=True)
    if cfg.frontend:
        cfg = cfg.replace(n_patches=0)    # token-only consistency check
    if cfg.n_experts:
        # capacity dropping is group-size dependent (GShard semantics), so
        # one-shot forward and stepwise decode only agree when dropless
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    params = M.init_params(keys, cfg)
    B, L_prompt, L_gen = 2, 16, 4
    max_len = L_prompt + L_gen
    toks = jax.random.randint(keys, (B, max_len), 0, cfg.vocab)

    full_logits, _, _ = M.forward(params, cfg, tokens=toks)

    prefill = make_prefill(cfg, B, max_len)
    decode = make_decode_step(cfg)
    cache, last = prefill(params, toks[:, :L_prompt])
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, L_prompt - 1]),
        rtol=0.15, atol=0.15)
    for i in range(L_gen):
        pos = L_prompt + i
        cache, lg = decode(params, cache, toks[:, pos:pos + 1],
                           jnp.int32(pos))
        ref = np.asarray(full_logits[:, pos], np.float32)
        got = np.asarray(lg, np.float32)
        # bf16 accumulation differences — compare argmax + coarse values
        np.testing.assert_allclose(got, ref, rtol=0.15, atol=0.15)


@pytest.mark.parametrize("arch", ["musicgen-medium", "llava-next-34b"])
def test_modality_stub_prefix(arch, keys):
    """Audio/VLM backbones consume precomputed frame/patch embeddings."""
    cfg = get_config(arch, tiny=True)
    assert cfg.frontend and cfg.n_patches > 0
    params = M.init_params(keys, cfg)
    B, L = 2, 12
    toks = jax.random.randint(keys, (B, L), 0, cfg.vocab)
    embeds = jax.random.normal(
        keys, (B, cfg.n_patches, cfg.d_model), cfg.dtype)
    logits, _, _ = M.forward(params, cfg, tokens=toks, embeds=embeds)
    assert logits.shape == (B, cfg.n_patches + L, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_swa_cache_is_window_bounded(keys):
    cfg = get_config("h2o-danube-1.8b", tiny=True)
    assert cfg.window == 32
    cache = M.init_cache(cfg, batch=2, max_len=4096)
    k = cache["layers"]["k"]
    assert k.shape[3] == cfg.window     # (layers, B, kv, window, hd)


def test_ssm_cache_is_constant_size(keys):
    cfg = get_config("falcon-mamba-7b", tiny=True)
    c1 = M.init_cache(cfg, batch=2, max_len=128)
    c2 = M.init_cache(cfg, batch=2, max_len=1 << 19)
    assert jax.tree_util.tree_map(lambda x: x.shape, c1) == \
        jax.tree_util.tree_map(lambda x: x.shape, c2)


def test_chunked_ce_matches_full(keys):
    """chunked_cross_entropy == plain CE (value and gradient)."""
    B, L, D, V = 2, 24, 16, 64
    h = jax.random.normal(keys, (B, L, D), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (D, V), jnp.float32)
    labels = jax.random.randint(keys, (B, L), 0, V)
    labels = labels.at[0, :3].set(-100)     # IGNORE positions

    def full(w):
        return cross_entropy(jnp.einsum("bld,dv->blv", h, w), labels)

    def chunked(w):
        return chunked_cross_entropy(
            h, labels, lambda hc: jnp.einsum("bld,dv->blv", hc, w), chunk=7)

    np.testing.assert_allclose(float(full(w)), float(chunked(w)), rtol=1e-6)
    g1 = jax.grad(full)(w)
    g2 = jax.grad(chunked)(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5,
                               atol=1e-6)


def test_moe_grouped_dispatch_balanced_routing(keys):
    """A perfectly balanced router must route with zero drops: MoE output
    equals running every token through its top-1 expert directly."""
    from repro.models import moe as moe_mod

    cfg = get_config("deepseek-v3-671b", tiny=True).replace(
        n_experts=4, top_k=1, n_shared_experts=0, moe_group_size=8,
        capacity_factor=2.0)
    params = moe_mod.moe_init(keys, cfg)
    B, L = 2, 16
    x = jax.random.normal(keys, (B, L, cfg.d_model), cfg.dtype)
    out, aux = moe_mod.moe_apply(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out, np.float32)).all()
    # Switch aux loss lower bound is 1 in exact arithmetic; bf16/fp32
    # softmax rounding can dip a couple percent below
    assert float(aux) >= 0.97


def test_padded_for_tp():
    cfg = get_config("yi-34b")          # 56 heads, 8 kv heads
    p = cfg.padded_for_tp(16)
    assert p.n_kv_heads == 16 and p.n_heads == 64
    assert p.hd == cfg.hd
    assert p.n_heads % p.n_kv_heads == 0
    cfg2 = get_config("zamba2-2.7b")    # 32/32 — already divisible
    assert cfg2.padded_for_tp(16) is cfg2
    mla = get_config("deepseek-v3-671b")
    assert mla.padded_for_tp(16) is mla  # 128 heads


def test_param_count_close_to_nominal():
    """Analytic param counts within tolerance of the arch's nominal size."""
    nominal = {
        "falcon-mamba-7b": 7e9,
        "yi-34b": 34e9,
        "phi4-mini-3.8b": 3.8e9,
        "glm4-9b": 9e9,
        "h2o-danube-1.8b": 1.8e9,
        "zamba2-2.7b": 2.7e9,
        "deepseek-v3-671b": 671e9,
    }
    for arch, n in nominal.items():
        got = get_config(arch).param_count()
        assert 0.7 * n < got < 1.35 * n, (arch, got, n)


def test_mla_absorbed_decode_equals_expanded(keys):
    """§Perf 4.1: the absorbed-matmul MLA decode is algebraically identical
    to the paper-faithful latent re-expansion."""
    cfg = get_config("deepseek-v3-671b", tiny=True).replace(
        param_dtype="float32")
    cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    params = M.init_params(keys, cfg)
    toks = jax.random.randint(keys, (2, 20), 0, cfg.vocab)
    prefill = make_prefill(cfg, 2, 20)
    cache, _ = prefill(params, toks[:, :16])
    dec_abs = make_decode_step(cfg)
    dec_exp = make_decode_step(cfg.replace(mla_absorb=False))
    c1 = jax.tree_util.tree_map(lambda x: x, cache)
    c2 = jax.tree_util.tree_map(lambda x: x, cache)
    for i in range(3):
        pos = 16 + i
        c1, lg1 = dec_abs(params, c1, toks[:, pos:pos + 1], jnp.int32(pos))
        c2, lg2 = dec_exp(params, c2, toks[:, pos:pos + 1], jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                                   rtol=1e-4, atol=1e-4)
