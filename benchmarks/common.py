"""Shared benchmark helpers: CSV emission + timing + JSON artifact dump."""
from __future__ import annotations

import json
import time
from contextlib import contextmanager

_ROWS = []
_RECORDS = []


def emit(bench: str, name: str, value, unit: str, **extra) -> None:
    tags = ",".join(f"{k}={v}" for k, v in extra.items())
    line = f"{bench},{name},{value},{unit}" + (f",{tags}" if tags else "")
    _ROWS.append(line)
    _RECORDS.append({"bench": bench, "name": name, "value": value,
                     "unit": unit, **extra})
    print(line, flush=True)


def dump_json(path: str) -> None:
    """Write every record emitted so far as a JSON array (CI artifact)."""
    with open(path, "w") as fh:
        json.dump(_RECORDS, fh, indent=1)
    print(f"wrote {len(_RECORDS)} records to {path}", flush=True)


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def header() -> None:
    print("bench,name,value,unit,tags", flush=True)
