"""Shared benchmark helpers: CSV emission + timing."""
from __future__ import annotations

import time
from contextlib import contextmanager

_ROWS = []


def emit(bench: str, name: str, value, unit: str, **extra) -> None:
    tags = ",".join(f"{k}={v}" for k, v in extra.items())
    line = f"{bench},{name},{value},{unit}" + (f",{tags}" if tags else "")
    _ROWS.append(line)
    print(line, flush=True)


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def header() -> None:
    print("bench,name,value,unit,tags", flush=True)
