"""Pallas TPU kernels for the compute hot-spots CRAFT-JAX optimizes:

* ``xor_parity`` — SCR partner-XOR parity encode/reconstruct (node tier),
* ``rs_erasure`` — GF(2^8) Reed–Solomon matmul: RS(k, m) erasure encode /
  syndrome / solve for the node tier's multi-loss redundancy (XOR is its
  m=1 row),
* ``checksum``   — blocked Fletcher-like integrity digest (device-side),
* ``flash_attention`` — blocked attention for the LM backbones.

Each subpackage ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd public wrapper with backend dispatch) and ``ref.py`` (pure-jnp oracle
used by the per-kernel allclose tests).
"""
