"""Device-resident snapshot pipeline (``CRAFT_DEVICE_SNAPSHOT``).

The host write path round-trips every checkpoint byte: a blocking
device→host copy per shard, then a host-side digest pass, then (for delta
writes) a digest-compare.  This module keeps that work on the accelerator:
one fused pass (``kernels.snapshot``) over the device-resident shard
produces per-chunk Fletcher digests, a dirty mask against the previous
snapshot's digests (kept device-resident between checkpoints), and the
byte-nibble histogram behind the zstd-vs-raw gate — and only the *dirty*
chunks are ever transferred to the host.

On an accelerator backend (``staged`` mode) host-side state per shard is a
**mirror**: a padded word buffer holding the exact bytes of the last
snapshot, patched chunk-wise from the device.  The mirror always equals the
live array's current bytes after ``snapshot()``, so every codec, tier and
delta base works unchanged downstream — the D2H traffic just shrinks to
the dirty fraction.  With ``double_buffer=True`` two mirrors alternate, so
an asynchronous writer can still be reading the previous version's mirror
while the next snapshot patches the other one; each mirror tracks its own
per-chunk digest table and fetches exactly the chunks that changed since
*it* was last current.  The previous snapshot's padded word buffer is
donated back to the packing computation, so the device-side staging buffer
is reused instead of re-allocated every checkpoint (double-buffered in
XLA's aliasing sense).

On CPU there is no transfer to shrink — ``np.asarray`` of a jax CPU array
is a zero-copy view of an immutable buffer — so no staging buffer or
mirror exists at all: the metadata pass fuses the byte-pack into its
reductions (one read of the array, nothing array-sized written) and the
zero-copy view is handed to the writer directly.  Immutability makes the
view snapshot-stable for free: a later update produces a *new* buffer,
while an in-flight asynchronous writer keeps the old one alive through
its view.

Fallbacks (host path, ``meta is None``): empty arrays, byte sizes not a
multiple of 4, complex dtypes, and any shape/dtype change — a reshape
resets the shard's state, which downstream means a full literal write.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import metrics
from repro.kernels.snapshot import ops as snapshot_ops

_LANES = 128


def _pack_words(x: jnp.ndarray, n_chunks: int, wpc: int) -> jnp.ndarray:
    """Flatten ``x`` and bit-cast its bytes to a zero-padded (n_chunks, wpc)
    uint32 matrix — little-endian, so it matches the host's
    ``view(np.uint32)`` of the same bytes exactly."""
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.uint8)     # same 1-byte 0/1 layout as numpy bool
    flat = x.reshape(-1)
    itemsize = np.dtype(x.dtype).itemsize
    if itemsize < 4:
        words = jax.lax.bitcast_convert_type(
            flat.reshape(-1, 4 // itemsize), jnp.uint32)
    elif itemsize == 4:
        words = jax.lax.bitcast_convert_type(flat, jnp.uint32)
    else:
        words = jax.lax.bitcast_convert_type(flat, jnp.uint32).reshape(-1)
    pad = n_chunks * wpc - words.shape[0]
    if pad:
        words = jnp.pad(words, (0, pad))
    return words.reshape(n_chunks, wpc)


@functools.partial(
    jax.jit,
    static_argnames=("n_chunks", "wpc", "with_hist", "use_pallas"))
def _fused(arr, prev, *, n_chunks, wpc, with_hist, use_pallas):
    """Pack + fused snapshot in one dispatch, so XLA can feed the digest
    pass straight from the packing reshape without a second memory walk."""
    words2 = _pack_words(arr, n_chunks, wpc)
    meta = snapshot_ops.snapshot_chunks(
        words2, prev, with_hist=with_hist, use_pallas=use_pallas)
    return words2, meta


@functools.partial(
    jax.jit,
    static_argnames=("n_chunks", "wpc", "with_hist", "use_pallas"),
    donate_argnums=(2,))
def _fused_donate(arr, prev, old_words, *, n_chunks, wpc, with_hist,
                  use_pallas):
    """Same, donating the previous snapshot's word buffer so XLA aliases the
    new one into its memory (device-side double buffering)."""
    del old_words
    words2 = _pack_words(arr, n_chunks, wpc)
    meta = snapshot_ops.snapshot_chunks(
        words2, prev, with_hist=with_hist, use_pallas=use_pallas)
    return words2, meta


class _ShardState:
    __slots__ = ("shape", "dtype", "n_chunks", "wpc", "prev_digests",
                 "words", "mirrors", "mirror_digs", "flip")

    def __init__(self, shape, dtype, n_chunks, wpc, buffers):
        self.shape = shape
        self.dtype = dtype
        self.n_chunks = n_chunks
        self.wpc = wpc
        self.prev_digests = None        # (n_chunks, 2) uint32, device
        self.words = None               # last padded word buffer (donation)
        self.mirrors = [None] * buffers
        self.mirror_digs = [None] * buffers
        self.flip = 0


class DeviceSnapshotter:
    """Per-checkpointable device snapshot state (one instance per Cp object,
    shards keyed by the caller — see ``JaxArrayCp`` / ``PytreeCp``)."""

    def __init__(self, chunk_bytes: int, *, with_hist: bool = True,
                 double_buffer: bool = True, staged: Optional[bool] = None):
        self.chunk_bytes = int(chunk_bytes)
        self.with_hist = with_hist
        self.buffers = 2 if double_buffer else 1
        # staged: device words buffer + host mirror (None = auto: only on
        # accelerator backends; CPU hands out zero-copy views instead)
        self.staged = staged
        self._state: dict = {}

    def reset(self) -> None:
        self._state.clear()

    def _grid(self, nbytes: int) -> Tuple[int, int]:
        """(n_chunks, words_per_chunk) matching the storage chunk grid; a
        single-chunk array pads only to the lane multiple, not a full chunk."""
        n_chunks = max(1, -(-nbytes // self.chunk_bytes))
        if n_chunks == 1:
            words = nbytes // 4
            wpc = max(_LANES, -(-words // _LANES) * _LANES)
        else:
            wpc = self.chunk_bytes // 4
        return n_chunks, wpc

    def snapshot(self, key, arr: jax.Array
                 ) -> Tuple[np.ndarray, Optional[dict]]:
        """Snapshot one device shard.  Returns ``(host_array, meta)`` where
        ``host_array`` equals ``np.asarray(arr)`` bit-for-bit and ``meta``
        is the device-produced chunk metadata for
        ``IOContext.record_device_meta`` — or ``None`` when the shard took
        the plain host path."""
        dtype = np.dtype(arr.dtype)
        nbytes = int(arr.size) * dtype.itemsize
        if (nbytes == 0 or nbytes % 4 or self.chunk_bytes % 4
                or dtype.kind == "c"):
            self._state.pop(key, None)
            return np.asarray(arr), None
        shape = tuple(arr.shape)
        n_chunks, wpc = self._grid(nbytes)

        st = self._state.get(key)
        if st is not None and (st.shape != shape or st.dtype != dtype
                               or st.n_chunks != n_chunks or st.wpc != wpc):
            st = None                   # reshape/regrid → full reset
        first = st is None
        if first:
            st = _ShardState(shape, dtype, n_chunks, wpc, self.buffers)
            self._state[key] = st

        backend = jax.default_backend()
        use_pallas = backend == "tpu" and wpc % _LANES == 0
        staged = self.staged if self.staged is not None else backend != "cpu"
        if not staged:
            # CPU: zero-copy view of the immutable buffer — snapshot-stable
            # without any mirror — and the numpy snapshot pass over it (the
            # checksum ops' numpy-on-CPU dispatch, one read, no packing).
            host = np.asarray(arr)
            prev_np = (st.prev_digests if st.prev_digests is not None
                       else np.zeros((n_chunks, 2), np.uint32))
            meta_host = snapshot_ops.snapshot_host(
                host.reshape(-1).view(np.uint8), self.chunk_bytes, prev_np)
            cur_dig = meta_host[:, :2]
            st.prev_digests = cur_dig
        else:
            donate = backend != "cpu"          # CPU jit ignores donation
            prev = (st.prev_digests if st.prev_digests is not None
                    else jnp.zeros((n_chunks, 2), jnp.uint32))
            kw = dict(n_chunks=n_chunks, wpc=wpc, with_hist=self.with_hist,
                      use_pallas=use_pallas)
            if donate and st.words is not None:
                words2, meta_dev = _fused_donate(arr, prev, st.words, **kw)
            else:
                words2, meta_dev = _fused(arr, prev, **kw)
            st.prev_digests = meta_dev[:, :2]
            st.words = words2 if donate else None
            meta_host = np.asarray(meta_dev)
            cur_dig = meta_host[:, :2]
            # Patch this round's mirror: fetch exactly the chunks whose
            # digest changed since the mirror was last current (a superset
            # of the device dirty column when double buffering skips a
            # round).
            mi = st.flip
            st.flip = (st.flip + 1) % self.buffers
            mirror = st.mirrors[mi]
            if mirror is None:
                mirror = st.mirrors[mi] = np.empty((n_chunks, wpc),
                                                   np.uint32)
                rows = np.arange(n_chunks)
            else:
                rows = np.flatnonzero(
                    (cur_dig != st.mirror_digs[mi]).any(axis=1))
            if rows.size == n_chunks:
                mirror[...] = np.asarray(words2)         # one full transfer
            elif rows.size:
                mirror[rows] = np.asarray(words2[rows])  # gather, dirty only
            st.mirror_digs[mi] = cur_dig.copy()
            host = (mirror.reshape(-1).view(np.uint8)[:nbytes]
                    .view(dtype).reshape(shape))

        entropy = None
        if staged and self.with_hist:     # numpy pass carries no histogram
            hist = meta_host[:, 3:].astype(np.int64)
            pad_bytes = n_chunks * wpc * 4 - nbytes
            if pad_bytes:       # padded zero bytes: 2 spurious bin-0 nibbles
                hist[-1, 0] -= 2 * pad_bytes
            entropy = [float(e)
                       for e in snapshot_ops.chunk_entropy_bits(hist)]
        meta = {
            "nbytes": nbytes,
            "chunk_bytes": self.chunk_bytes,
            "rdigests": cur_dig.astype(np.int64).tolist(),
            "dirty": (None if first
                      else meta_host[:, 2].astype(bool).tolist()),
            "entropy_bits": entropy,
        }
        if metrics.REGISTRY.enabled:   # keep the unset path numpy-free
            if meta["dirty"] is not None:
                metrics.set_gauge(
                    "snapshot_dirty_fraction",
                    sum(meta["dirty"]) / max(1, n_chunks))
            if staged:
                metrics.inc("snapshot_d2h_bytes", int(rows.size) * wpc * 4)
                metrics.inc("snapshot_d2h_bytes_saved",
                            (n_chunks - int(rows.size)) * wpc * 4)
        return host, meta
