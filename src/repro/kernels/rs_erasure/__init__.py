from repro.kernels.rs_erasure.ops import (  # noqa: F401
    decode_lost,
    encode_parity,
    gf_matmul,
    rs_matrix,
)
